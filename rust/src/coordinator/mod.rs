//! The host-machine coordinator (paper §V-A: the host streams sample
//! data to the chip, collects results, and repeats). This is the Layer-3
//! driver that owns a deployed chip: it injects input packets per
//! timestep, gathers readout values, clears dynamic state between
//! samples, and drives the on-chip learning loop (error injection for
//! the BCI cross-day fine-tune).
//!
//! # The incremental step contract
//!
//! The chip's native I/O is AER-style and per-timestep, so the
//! coordinator's primitive is too: [`Deployment::step_events`] takes one
//! timestep of host events ([`StepEvents`] — active spike channels or a
//! dense FP row) and returns one [`StepRow`] (the readout row plus
//! step-local spike/packet counts). Whole-sample entry points
//! (`run_spikes` / `run_values`) are thin loops over it, which is what
//! lets the `api` layer expose both batch (`Session::run`) and streaming
//! (`Session::open_stream`) execution over the same engine with
//! bit-identical results.
//!
//! [`MultiChipDeployment`] is the sharded counterpart: it owns one
//! [`Chip`] per die of a [`ShardedCompiled`] image and advances them in
//! lockstep one barrier-step at a time. Each step, every die (in
//! ascending id order) drains its inbound bridge cells — packets from
//! lower-numbered dies are delivered *before* its own pending spikes,
//! packets from higher dies and host inputs after, reproducing the
//! single-die ascending-source order — steps its [`Chip`], and stages the
//! step's [`StepResult::egress`] packets (fan-out edges the compiler
//! marked [`RouteMode::Remote`]) for the destination dies' *next* step.
//! Because the bridge is double-buffered by step parity, a die can never
//! observe a packet staged in the current step, so stepping the dies
//! sequentially on the host thread is semantically identical to the
//! barrier-synchronized thread-per-die variant this replaces — and it
//! makes single-step streaming cheap (no per-step thread spawn). Cross-
//! die spikes arrive with exactly the one-timestep latency of on-die NoC
//! delivery, which is what makes a sharded run bit-identical to the same
//! network on one (hypothetically larger) die.

use std::sync::Arc;

use crate::chip::{config::ChipConfig, Chip, ChipActivity, StepResult, StepSchedule};
use crate::compiler::shard::ShardedCompiled;
use crate::compiler::Compiled;
use crate::datasets::{DenseSample, SpikeSample};
use crate::nc::Trap;
use crate::noc::Packet;
use crate::topology::RouteMode;
use crate::util::F16;

/// One timestep of host input — the union of the two injection modes of
/// §III-B, borrowed from the caller (no per-step allocation).
#[derive(Clone, Copy, Debug)]
pub enum StepEvents<'a> {
    /// Active spike channels this timestep (AER-style event list). An
    /// empty slice is a quiet step (stream drain / idle tick).
    Spikes(&'a [u16]),
    /// Dense FP values for every channel; zero bins carry no information
    /// and are skipped at injection (stay sparse).
    Dense(&'a [f32]),
}

/// One timestep's host-visible result: the streaming unit.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StepRow {
    /// Readout row: one value per output neuron (zeros where no readout
    /// emitted this step).
    pub row: Vec<f32>,
    /// Spikes minted this step.
    pub spikes: u64,
    /// Packets routed this step.
    pub packets: u64,
}

/// A deployed model: chip + compilation metadata. The compiled image is
/// behind an [`Arc`] so `run_batch` forks share it instead of deep-
/// cloning ~the whole deployment per worker.
pub struct Deployment {
    pub chip: Chip,
    pub compiled: Arc<Compiled>,
    n_outputs: usize,
    /// Reused per-step host packet buffer (allocation-free stepping).
    in_packets: Vec<Packet>,
    /// Reused per-step chip result.
    step_res: StepResult,
}

/// Per-sample run result: readout values per timestep.
#[derive(Clone, Debug)]
pub struct SampleRun {
    /// `outputs[t][k]` = readout neuron k's value at timestep t.
    pub outputs: Vec<Vec<f32>>,
    pub spikes: u64,
    pub packets: u64,
}

impl SampleRun {
    /// Sum of readout values across timesteps (rate-style decoding).
    pub fn summed(&self) -> Vec<f32> {
        let k = self.outputs.first().map(|o| o.len()).unwrap_or(0);
        let mut s = vec![0.0; k];
        for row in &self.outputs {
            for (i, v) in row.iter().enumerate() {
                s[i] += v;
            }
        }
        s
    }
}

impl Deployment {
    /// Configure a fresh chip with a compiled deployment (INIT stage).
    /// Fails with a [`Trap`] when the image addresses memory outside the
    /// die (a code-generator bug, surfaced instead of panicking).
    pub fn new(compiled: Compiled) -> Result<Deployment, Trap> {
        Deployment::from_image(Arc::new(compiled))
    }

    /// Deploy an already-shared compiled image on a fresh chip — the
    /// `run_batch` fork path: each worker allocates only chip state
    /// (sized by [`Compiled::data_words`], not the fixed 64 KB/NC
    /// maximum), never a copy of the image.
    pub fn from_image(compiled: Arc<Compiled>) -> Result<Deployment, Trap> {
        let mut chip = Chip::new(compiled.data_words.max(64));
        chip.configure(&compiled.config)?;
        if let Some(prog) = &compiled.schedule {
            chip.schedule = StepSchedule::Static(Arc::new(prog.clone()));
        }
        let n_outputs = compiled.readout.len();
        Ok(Deployment {
            chip,
            compiled,
            n_outputs,
            in_packets: Vec::new(),
            step_res: StepResult::default(),
        })
    }

    pub fn config(&self) -> &ChipConfig {
        &self.compiled.config
    }

    /// Advance one SNN timestep with one timestep of host events and
    /// collect its readout row — the incremental primitive everything
    /// else (whole-sample runs, the api layer's streams) wraps. Apart
    /// from the returned row the step is allocation-free: the host
    /// packet list and chip step result persist across calls.
    ///
    /// Events now arrive straight from untrusted clients (the serving
    /// pool), so out-of-range channels are a typed [`Trap`], never a
    /// panic — one bad push must not take down the host process.
    pub fn step_events(&mut self, ev: StepEvents<'_>) -> Result<StepRow, Trap> {
        let Deployment {
            chip,
            compiled,
            n_outputs,
            in_packets,
            step_res,
        } = self;
        in_packets.clear();
        let channels = compiled.config.input_map.len();
        match ev {
            StepEvents::Spikes(active) => {
                for &ch in active {
                    let Some(tpls) = compiled.config.input_map.get(ch as usize) else {
                        return Err(host_trap(format!(
                            "input channel {ch} outside the {channels}-channel \
                             input layer"
                        )));
                    };
                    in_packets.extend(tpls.iter().copied());
                }
            }
            StepEvents::Dense(row) => {
                if row.len() > channels {
                    return Err(host_trap(format!(
                        "dense row carries {} values but the input layer has \
                         {channels} channels",
                        row.len()
                    )));
                }
                for (ch, &v) in row.iter().enumerate() {
                    if v == 0.0 {
                        continue; // zero bins carry no information: stay sparse
                    }
                    for tpl in &compiled.config.input_map[ch] {
                        let mut p = *tpl;
                        p.payload = F16::from_f32(v).0;
                        in_packets.push(p);
                    }
                }
            }
        }
        chip.step_into(in_packets, step_res)?;
        let mut row = vec![0.0f32; *n_outputs];
        for h in &step_res.outputs {
            if let Some(&k) = compiled.readout.get(&(h.cc, h.nc, h.neuron)) {
                row[k] = F16(h.value).to_f32();
            }
        }
        Ok(StepRow {
            row,
            spikes: step_res.spikes,
            packets: step_res.packets_routed,
        })
    }

    /// Run one spike-train sample (ECG / SHD style inputs): a loop over
    /// [`Deployment::step_events`].
    pub fn run_spikes(&mut self, sample: &SpikeSample) -> Result<SampleRun, Trap> {
        let mut run = SampleRun {
            outputs: Vec::with_capacity(sample.spikes.len()),
            spikes: 0,
            packets: 0,
        };
        for active in &sample.spikes {
            let sr = self.step_events(StepEvents::Spikes(active))?;
            run.spikes += sr.spikes;
            run.packets += sr.packets;
            run.outputs.push(sr.row);
        }
        Ok(run)
    }

    /// Run one dense-valued sample (BCI binned rates — FP input mode).
    pub fn run_values(&mut self, sample: &DenseSample) -> Result<SampleRun, Trap> {
        let mut run = SampleRun {
            outputs: Vec::with_capacity(sample.values.len()),
            spikes: 0,
            packets: 0,
        };
        for row in &sample.values {
            let sr = self.step_events(StepEvents::Dense(row))?;
            run.spikes += sr.spikes;
            run.packets += sr.packets;
            run.outputs.push(sr.row);
        }
        Ok(run)
    }

    /// Inject per-output-neuron errors and trigger the on-chip learning
    /// update (one Learn sweep in the next FIRE stage).
    pub fn learn_step(&mut self, errors: &[f32]) -> Result<(), Trap> {
        assert_eq!(errors.len(), self.compiled.error_map.len());
        let mut packets = Vec::with_capacity(errors.len());
        for (k, &e) in errors.iter().enumerate() {
            let mut p = self.compiled.error_map[k];
            p.payload = F16::from_f32(e).0;
            packets.push(p);
        }
        // deliver errors (INTEG) and run a FIRE stage (Learn events fire
        // because the head cores are configured with `learn = true`)
        self.chip.step(&packets)?;
        Ok(())
    }

    /// Zero all dynamic state (membrane, currents, adaptation, learning
    /// accumulators, errors) and put the wake sets back to sleep —
    /// between samples. Weights and parameters survive. Fails with a
    /// [`Trap`] if a compiled core layout addresses memory outside its
    /// NC (a compiler bug, surfaced instead of panicking).
    pub fn reset_state(&mut self) -> Result<(), Trap> {
        self.chip.flush_packets();
        // one shared zero buffer, grown to the largest region — this
        // runs before every sample, so no per-core allocations
        let mut zeros: Vec<u16> = Vec::new();
        for k in 0..self.compiled.cores.len() {
            let core = &self.compiled.cores[k];
            let (cc, nc, l) = (core.cc, core.nc, core.layout);
            // [cur, params) — currents + membrane
            let n = (l.params - l.cur) as usize;
            // [adapt, itof) — adaptation, acc counters, errors
            let n2 = (l.itof - l.adapt) as usize;
            if zeros.len() < n.max(n2) {
                zeros.resize(n.max(n2), 0);
            }
            self.chip.poke(cc, nc, l.cur, &zeros[..n])?;
            self.chip.poke(cc, nc, l.adapt, &zeros[..n2])?;
        }
        Ok(())
    }

    /// Read back a weight region (host monitoring path) — used by tests
    /// and the learning demo to show weights actually moved.
    pub fn peek_weights(&self, core_idx: usize, n: usize) -> Result<Vec<f32>, Trap> {
        let core = &self.compiled.cores[core_idx];
        Ok(self
            .chip
            .peek(core.cc, core.nc, core.layout.weights, n)?
            .into_iter()
            .map(|w| F16(w).to_f32())
            .collect())
    }
}

// ---------------------------------------------------------------------
// Multi-chip lockstep deployment.
// ---------------------------------------------------------------------

/// Host-side inter-die packet staging: `stage[parity][dst][src]` holds
/// the packets die `src` minted during a step of the given parity, to be
/// delivered to die `dst` in the next step. Double-buffering by step
/// parity is what decouples steps: writers fill the other parity while
/// readers drain their own, so no die can see a packet staged in the
/// step that is currently executing — the invariant that makes the
/// sequential per-die loop equivalent to barrier-synchronized lockstep
/// threads.
struct Bridge {
    stage: [Vec<Vec<Vec<Packet>>>; 2],
    /// Parity of the next lockstep step.
    parity: usize,
}

impl Bridge {
    fn new(n: usize) -> Bridge {
        let mk = || (0..n).map(|_| vec![Vec::new(); n]).collect();
        Bridge {
            stage: [mk(), mk()],
            parity: 0,
        }
    }

    fn clear(&mut self) {
        for half in &mut self.stage {
            for row in half {
                for cell in row {
                    cell.clear();
                }
            }
        }
    }
}

fn host_trap(msg: impl Into<String>) -> Trap {
    Trap {
        pc: 0,
        msg: msg.into(),
    }
}

/// N dies of one sharded model, stepped in lockstep one step at a time.
///
/// Each [`MultiChipDeployment::step_events`] call advances every die by
/// one timestep in ascending die order (see the module docs for why that
/// order is unobservable), delivering inbound bridge packets in the
/// single-die ascending-source order: lower-numbered dies before the
/// die's own pending spikes, higher-numbered dies and host inputs after.
/// State reset, learning, and activity aggregation mirror the single-die
/// [`Deployment`] surface so the API layer can treat both uniformly.
pub struct MultiChipDeployment {
    pub chips: Vec<Chip>,
    pub compiled: Arc<ShardedCompiled>,
    bridge: Bridge,
    /// Cumulative per-edge bridge traffic: `bridge_packets[src][dst]`
    /// counts the packets die `src` staged for die `dst` since
    /// deployment (the measured counterpart of the compiler's
    /// `cut_traffic` estimate and the fast backend's
    /// [`ChipActivity::remote_packets`]).
    bridge_packets: Vec<Vec<u64>>,
    /// Reused per-step host packet staging, one cell per die.
    host_stage: Vec<Vec<Packet>>,
    /// Reused pre/post injection buffers (bridge packets from lower /
    /// higher dies, see [`Chip::step_ext`]).
    pre: Vec<Packet>,
    post: Vec<Packet>,
    /// Reused per-die chip step result.
    step_res: StepResult,
}

impl MultiChipDeployment {
    /// Configure one fresh chip per die (INIT stage on every die).
    pub fn new(compiled: Arc<ShardedCompiled>) -> Result<MultiChipDeployment, Trap> {
        if compiled.chips.is_empty() {
            return Err(host_trap("sharded image carries zero dies"));
        }
        // A Remote route naming a die outside this fleet would index
        // straight past the bridge tables mid-run; refuse at deploy time
        // with coordinates instead (the static verifier reports the same
        // condition as `RemoteChipRange` at compile time).
        let dies = compiled.chips.len();
        for (die, image) in compiled.chips.iter().enumerate() {
            for (&cc, cc_img) in &image.config.ccs {
                for ie in &cc_img.tables.fanout_it {
                    if let RouteMode::Remote { chip, .. } = ie.mode {
                        if chip as usize >= dies {
                            return Err(host_trap(format!(
                                "die {die} cc {cc}: remote route targets die \
                                 {chip} of a {dies}-die fleet"
                            )));
                        }
                    }
                }
            }
        }
        let mut chips = Vec::with_capacity(compiled.chips.len());
        for (die, image) in compiled.chips.iter().enumerate() {
            let mut chip = Chip::new(compiled.data_words.max(64));
            chip.configure(&image.config)?;
            if let Some(prog) = compiled.schedules.get(die) {
                chip.schedule = StepSchedule::Static(Arc::new(prog.clone()));
            }
            chips.push(chip);
        }
        Ok(MultiChipDeployment {
            bridge: Bridge::new(chips.len()),
            bridge_packets: vec![vec![0; chips.len()]; chips.len()],
            host_stage: vec![Vec::new(); chips.len()],
            pre: Vec::new(),
            post: Vec::new(),
            step_res: StepResult::default(),
            chips,
            compiled,
        })
    }

    pub fn num_chips(&self) -> usize {
        self.chips.len()
    }

    /// Cumulative per-edge bridge traffic, `[src][dst]`. The diagonal is
    /// always zero (a die never bridges to itself), and the total equals
    /// the aggregate [`ChipActivity::remote_packets`].
    pub fn bridge_traffic(&self) -> &[Vec<u64>] {
        &self.bridge_packets
    }

    /// Advance every die by one lockstep timestep with one timestep of
    /// host events, and collect the fleet's readout row — the multi-die
    /// counterpart of [`Deployment::step_events`]. Out-of-range client
    /// events are a typed [`Trap`], never a panic.
    pub fn step_events(&mut self, ev: StepEvents<'_>) -> Result<StepRow, Trap> {
        for cell in &mut self.host_stage {
            cell.clear();
        }
        let channels = self.compiled.input_map.len();
        match ev {
            StepEvents::Spikes(active) => {
                for &ch in active {
                    let Some(tpls) = self.compiled.input_map.get(ch as usize) else {
                        return Err(host_trap(format!(
                            "input channel {ch} outside the {channels}-channel \
                             input layer"
                        )));
                    };
                    for (chip, tpl) in tpls {
                        self.host_stage[*chip].push(*tpl);
                    }
                }
            }
            StepEvents::Dense(row) => {
                if row.len() > channels {
                    return Err(host_trap(format!(
                        "dense row carries {} values but the input layer has \
                         {channels} channels",
                        row.len()
                    )));
                }
                for (ch, &v) in row.iter().enumerate() {
                    if v == 0.0 {
                        continue; // zero bins carry no information: stay sparse
                    }
                    for (chip, tpl) in &self.compiled.input_map[ch] {
                        let mut p = *tpl;
                        p.payload = F16::from_f32(v).0;
                        self.host_stage[*chip].push(p);
                    }
                }
            }
        }
        self.step_staged()
    }

    /// Run one spike-train sample across all dies: a loop over
    /// [`MultiChipDeployment::step_events`].
    pub fn run_spikes(&mut self, sample: &SpikeSample) -> Result<SampleRun, Trap> {
        let mut run = SampleRun {
            outputs: Vec::with_capacity(sample.spikes.len()),
            spikes: 0,
            packets: 0,
        };
        for active in &sample.spikes {
            let sr = self.step_events(StepEvents::Spikes(active))?;
            run.spikes += sr.spikes;
            run.packets += sr.packets;
            run.outputs.push(sr.row);
        }
        Ok(run)
    }

    /// Run one dense-valued sample (FP input mode) across all dies.
    pub fn run_values(&mut self, sample: &DenseSample) -> Result<SampleRun, Trap> {
        let mut run = SampleRun {
            outputs: Vec::with_capacity(sample.values.len()),
            spikes: 0,
            packets: 0,
        };
        for row in &sample.values {
            let sr = self.step_events(StepEvents::Dense(row))?;
            run.spikes += sr.spikes;
            run.packets += sr.packets;
            run.outputs.push(sr.row);
        }
        Ok(run)
    }

    /// Inject per-output errors on the head die(s) and run one lockstep
    /// learning sweep — the multi-die equivalent of
    /// [`Deployment::learn_step`].
    pub fn learn_step(&mut self, errors: &[f32]) -> Result<(), Trap> {
        assert_eq!(errors.len(), self.compiled.error_map.len());
        for cell in &mut self.host_stage {
            cell.clear();
        }
        for (k, &e) in errors.iter().enumerate() {
            let (chip, tpl) = self.compiled.error_map[k];
            let mut p = tpl;
            p.payload = F16::from_f32(e).0;
            self.host_stage[chip].push(p);
        }
        self.step_staged()?;
        Ok(())
    }

    /// Zero all dynamic state on every die and drop in-flight bridge
    /// packets — between samples. Weights and parameters survive.
    pub fn reset_state(&mut self) -> Result<(), Trap> {
        for chip in &mut self.chips {
            chip.flush_packets();
        }
        self.bridge.clear();
        let mut zeros: Vec<u16> = Vec::new();
        for (chip_idx, core) in &self.compiled.cores {
            let (cc, nc, l) = (core.cc, core.nc, core.layout);
            let n = (l.params - l.cur) as usize;
            let n2 = (l.itof - l.adapt) as usize;
            if zeros.len() < n.max(n2) {
                zeros.resize(n.max(n2), 0);
            }
            let chip = &mut self.chips[*chip_idx];
            chip.poke(cc, nc, l.cur, &zeros[..n])?;
            chip.poke(cc, nc, l.adapt, &zeros[..n2])?;
        }
        Ok(())
    }

    /// Read back a weight region from the die hosting `core_idx` — the
    /// multi-die counterpart of [`Deployment::peek_weights`], used by
    /// the differential fuzz oracle to compare post-learning weights
    /// bit-exactly across shard counts.
    pub fn peek_weights(&self, core_idx: usize, n: usize) -> Result<Vec<f32>, Trap> {
        let (chip_idx, core) = &self.compiled.cores[core_idx];
        Ok(self.chips[*chip_idx]
            .peek(core.cc, core.nc, core.layout.weights, n)?
            .into_iter()
            .map(|w| F16(w).to_f32())
            .collect())
    }

    /// Aggregate activity across dies: event counters sum; `timesteps`
    /// is the lockstep step count (every die steps together), not the
    /// per-die sum, so energy/throughput math sees wall-clock steps.
    pub fn activity(&self) -> ChipActivity {
        let mut total = ChipActivity::default();
        for chip in &self.chips {
            let a = chip.activity();
            total.nc.add(&a.nc);
            total.dt_reads += a.dt_reads;
            total.it_reads += a.it_reads;
            total.activations += a.activations;
            total.packets += a.packets;
            total.link_traversals += a.link_traversals;
            total.remote_packets += a.remote_packets;
            total.timesteps = total.timesteps.max(a.timesteps);
        }
        total
    }

    /// Per-die activity (per-die vs aggregate metrics in the docs).
    pub fn activity_per_chip(&self) -> Vec<ChipActivity> {
        self.chips.iter().map(|c| c.activity()).collect()
    }

    /// The lockstep core: one timestep of every die over the staged host
    /// packets (`host_stage`), in ascending die order. A [`Trap`] on die
    /// `i` leaves earlier dies already stepped — in-flight state is
    /// meaningless after a fault, so callers recover via `reset_state`
    /// (per-edge bridge counters booked before the fault are kept, which
    /// is what keeps the bridge matrix equal to the chips' own egress
    /// counters even across failures).
    fn step_staged(&mut self) -> Result<StepRow, Trap> {
        let n = self.chips.len();
        let parity = self.bridge.parity;
        self.bridge.parity ^= 1;
        let MultiChipDeployment {
            chips,
            compiled,
            bridge,
            bridge_packets,
            host_stage,
            pre,
            post,
            step_res,
        } = self;
        let mut out = StepRow {
            row: vec![0.0f32; compiled.n_outputs],
            spikes: 0,
            packets: 0,
        };
        for i in 0..n {
            // Inbound bridge packets: lower-numbered dies land before
            // this die's own pending spikes, higher-numbered dies and
            // host inputs after — the single-die ascending-source order.
            pre.clear();
            post.clear();
            for src in 0..n {
                let cell = &mut bridge.stage[parity][i][src];
                if src < i {
                    pre.append(cell);
                } else if src > i {
                    post.append(cell);
                }
            }
            post.extend_from_slice(&host_stage[i]);
            chips[i].step_ext(pre, post, step_res)?;
            out.spikes += step_res.spikes;
            out.packets += step_res.packets_routed;
            for h in &step_res.outputs {
                if let Some(&k) = compiled.chips[i].readout.get(&(h.cc, h.nc, h.neuron))
                {
                    out.row[k] = F16(h.value).to_f32();
                }
            }
            // Stage this die's cross-die egress for the next step.
            for p in &step_res.egress {
                if let RouteMode::Remote { chip: dst, x, y } = p.mode {
                    bridge_packets[i][dst as usize] += 1;
                    bridge.stage[parity ^ 1][dst as usize][i].push(Packet {
                        mode: RouteMode::Unicast { x, y },
                        ..*p
                    });
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{self, Options};
    use crate::datasets::SpikeSample;
    use crate::model;

    /// A hand-buildable 2-layer net: 4 inputs → 3 LIF → 2 readout.
    fn tiny_net() -> (model::NetDef, Vec<Vec<f32>>) {
        let mut net = model::NetDef::new("tiny", 5);
        net.layers.push(model::Layer::Input { size: 4 });
        net.layers.push(model::Layer::Fc {
            input: 4,
            output: 3,
            neuron: model::NeuronModel::Lif { tau: 0.5, vth: 0.9 },
        });
        net.layers.push(model::Layer::Fc {
            input: 3,
            output: 2,
            neuron: model::NeuronModel::Readout { tau: 0.5 },
        });
        // input->hidden: channel i drives neuron i%3 strongly
        let mut w1 = vec![0.0f32; 4 * 3];
        for i in 0..4 {
            w1[i * 3 + i % 3] = 1.0;
        }
        // hidden->readout: neuron 0,1 -> out 0; neuron 2 -> out 1
        let w2 = vec![0.6, 0.0, 0.6, 0.0, 0.0, 0.6];
        (net, vec![vec![], w1, w2])
    }

    fn deploy(net: &model::NetDef, weights: &[Vec<f32>], learning: bool) -> Deployment {
        let r = compiler::compile(
            net,
            weights,
            &Options {
                learning,
                sa_iters: 200,
                ..Default::default()
            },
        )
        .unwrap();
        Deployment::new(r.compiled).unwrap()
    }

    #[test]
    fn end_to_end_spike_flow_reaches_readout() {
        let (net, weights) = tiny_net();
        let mut d = deploy(&net, &weights, false);
        // drive channel 0 every step: hidden neuron 0 fires, readout 0
        // integrates (2-step pipeline latency: t spike -> t+1 hidden
        // fires -> t+2 readout sees it)
        let sample = SpikeSample {
            spikes: vec![vec![0u16]; 6],
            labels: vec![0],
        };
        let run = d.run_spikes(&sample).unwrap();
        assert!(run.spikes > 0, "hidden layer never fired");
        let summed = run.summed();
        assert!(
            summed[0] > summed[1],
            "readout 0 should dominate: {summed:?}"
        );
    }

    #[test]
    fn step_events_is_the_run_spikes_loop_body() {
        // pushing the sample one timestep at a time must be bit-identical
        // to the whole-sample entry point (the streaming contract)
        let (net, weights) = tiny_net();
        let sample = SpikeSample {
            spikes: vec![vec![0u16, 2], vec![], vec![1, 3], vec![], vec![0]],
            labels: vec![0],
        };
        let mut whole = deploy(&net, &weights, false);
        let run = whole.run_spikes(&sample).unwrap();

        let mut stepped = deploy(&net, &weights, false);
        let mut rows = Vec::new();
        let mut spikes = 0u64;
        let mut packets = 0u64;
        for active in &sample.spikes {
            let sr = stepped.step_events(StepEvents::Spikes(active)).unwrap();
            rows.push(sr.row);
            spikes += sr.spikes;
            packets += sr.packets;
        }
        assert_eq!(run.outputs, rows);
        assert_eq!(run.spikes, spikes);
        assert_eq!(run.packets, packets);
        assert_eq!(whole.chip.activity(), stepped.chip.activity());
    }

    #[test]
    fn reset_state_silences_the_chip() {
        let (net, weights) = tiny_net();
        let mut d = deploy(&net, &weights, false);
        let sample = SpikeSample {
            spikes: vec![vec![0u16, 1, 2, 3]; 4],
            labels: vec![0],
        };
        d.run_spikes(&sample).unwrap();
        d.reset_state().unwrap();
        // with no input, a reset chip must produce zero readout
        let quiet = SpikeSample {
            spikes: vec![vec![]; 3],
            labels: vec![0],
        };
        let run = d.run_spikes(&quiet).unwrap();
        assert_eq!(run.spikes, 0);
        assert!(run.summed().iter().all(|&v| v == 0.0), "{:?}", run.summed());
    }

    #[test]
    fn weights_survive_reset() {
        let (net, weights) = tiny_net();
        let mut d = deploy(&net, &weights, false);
        let before = d.peek_weights(0, 6).unwrap();
        d.reset_state().unwrap();
        assert_eq!(before, d.peek_weights(0, 6).unwrap());
        assert!(before.iter().any(|&w| w != 0.0));
    }

    #[test]
    fn srnn_recurrence_sustains_activity() {
        // recurrent weights keep the hidden layer firing after input stops
        let mut net = model::NetDef::new("rec", 8);
        net.layers.push(model::Layer::Input { size: 2 });
        net.layers.push(model::Layer::Recurrent {
            input: 2,
            size: 4,
            neuron: model::NeuronModel::Lif { tau: 0.9, vth: 0.5 },
        });
        net.layers.push(model::Layer::Fc {
            input: 4,
            output: 1,
            neuron: model::NeuronModel::Readout { tau: 0.9 },
        });
        // strong input + strong self-excitation
        let mut w1 = vec![0.0f32; (2 + 4) * 4];
        for i in 0..2 {
            w1[i * 4 + i] = 1.0; // input i -> hidden i
        }
        for j in 0..4 {
            w1[(2 + j) * 4 + (j + 1) % 4] = 0.8; // ring recurrence
        }
        let w2 = vec![0.5; 4];
        let mut d = deploy(&net, &vec![vec![], w1, w2], false);
        // one input burst at t=0 only
        let mut spikes = vec![vec![]; 8];
        spikes[0] = vec![0u16, 1];
        let run = d
            .run_spikes(&SpikeSample { spikes, labels: vec![0] })
            .unwrap();
        // ring should keep spiking well past the input burst
        assert!(run.spikes >= 4, "recurrence died: {} spikes", run.spikes);
    }

    #[test]
    fn on_chip_learning_moves_head_weights() {
        let net = model::bci_net(2);
        let n_in = 2 * 8;
        let mut w = Vec::new();
        w.push(vec![]);
        // sparse blobs
        let mut w1 = vec![0.0f32; 128 * 16];
        for t in 0..16 {
            for k in 0..8 {
                w1[((t * 8 + k) % 128) * 16 + t] = 0.3;
            }
        }
        w.push(w1);
        let mut w2 = vec![0.0f32; 16 * 16];
        for t in 0..16 {
            w2[((t * 3) % 16) * 16 + t] = 1.5; // strong enough to relay spikes
        }
        w.push(w2);
        w.push(vec![0.05f32; n_in * 4]);
        let mut d = deploy(&net, &w, true);

        // find the head core (layer 3)
        let head = d
            .compiled
            .cores
            .iter()
            .position(|c| c.parts.iter().any(|p| p.0 == 3))
            .unwrap();
        let before = d.peek_weights(head, 8).unwrap();
        // run a real dense sample so layer-2 spikes reach the head and
        // charge its presynaptic accumulators, then inject errors
        let s = crate::datasets::bci::sample(0, 0, &mut crate::util::Rng::new(3));
        let run = d.run_values(&s).unwrap();
        assert!(run.spikes > 0, "no spikes reached the head");
        d.learn_step(&[0.5, -0.5, 0.25, -0.25]).unwrap();
        let after = d.peek_weights(head, 8).unwrap();
        assert_ne!(before, after, "learning did not touch the head weights");
    }
}
