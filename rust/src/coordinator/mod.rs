//! The host-machine coordinator (paper §V-A: the host streams sample
//! data to the chip, collects results, and repeats). This is the Layer-3
//! driver that owns a deployed chip: it injects input packets per
//! timestep, gathers readout values, clears dynamic state between
//! samples, and drives the on-chip learning loop (error injection for
//! the BCI cross-day fine-tune).
//!
//! [`MultiChipDeployment`] is the sharded counterpart: it owns one
//! [`Chip`] per die of a [`ShardedCompiled`] image and steps them in
//! lockstep — one std thread per die, one barrier per timestep — while a
//! host-side bridge carries each die's [`StepResult::egress`] packets
//! (fan-out edges the compiler marked [`RouteMode::Remote`]) into the
//! destination die's next step. Cross-die spikes therefore arrive with
//! exactly the one-timestep latency of on-die NoC delivery, and in the
//! same ascending-source order, which is what makes a sharded run
//! bit-identical to the same network on one (hypothetically larger) die.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier, Mutex};

use crate::chip::{config::ChipConfig, Chip, ChipActivity, StepResult};
use crate::compiler::shard::ShardedCompiled;
use crate::compiler::Compiled;
use crate::datasets::{DenseSample, SpikeSample};
use crate::nc::Trap;
use crate::noc::Packet;
use crate::scheduler::HostOutput;
use crate::topology::RouteMode;
use crate::util::F16;

/// A deployed model: chip + compilation metadata. The compiled image is
/// behind an [`Arc`] so `run_batch` forks share it instead of deep-
/// cloning ~the whole deployment per worker.
pub struct Deployment {
    pub chip: Chip,
    pub compiled: Arc<Compiled>,
    n_outputs: usize,
}

/// Per-sample run result: readout values per timestep.
#[derive(Clone, Debug)]
pub struct SampleRun {
    /// `outputs[t][k]` = readout neuron k's value at timestep t.
    pub outputs: Vec<Vec<f32>>,
    pub spikes: u64,
    pub packets: u64,
}

impl SampleRun {
    /// Sum of readout values across timesteps (rate-style decoding).
    pub fn summed(&self) -> Vec<f32> {
        let k = self.outputs.first().map(|o| o.len()).unwrap_or(0);
        let mut s = vec![0.0; k];
        for row in &self.outputs {
            for (i, v) in row.iter().enumerate() {
                s[i] += v;
            }
        }
        s
    }
}

impl Deployment {
    /// Configure a fresh chip with a compiled deployment (INIT stage).
    /// Fails with a [`Trap`] when the image addresses memory outside the
    /// die (a code-generator bug, surfaced instead of panicking).
    pub fn new(compiled: Compiled) -> Result<Deployment, Trap> {
        Deployment::from_image(Arc::new(compiled))
    }

    /// Deploy an already-shared compiled image on a fresh chip — the
    /// `run_batch` fork path: each worker allocates only chip state
    /// (sized by [`Compiled::data_words`], not the fixed 64 KB/NC
    /// maximum), never a copy of the image.
    pub fn from_image(compiled: Arc<Compiled>) -> Result<Deployment, Trap> {
        let mut chip = Chip::new(compiled.data_words.max(64));
        chip.configure(&compiled.config)?;
        let n_outputs = compiled.readout.len();
        Ok(Deployment {
            chip,
            compiled,
            n_outputs,
        })
    }

    pub fn config(&self) -> &ChipConfig {
        &self.compiled.config
    }

    /// Run one spike-train sample (ECG / SHD style inputs). The input
    /// packet list and chip step result are reused across timesteps, so
    /// the per-step loop is allocation-free apart from the readout rows
    /// it returns.
    pub fn run_spikes(&mut self, sample: &SpikeSample) -> Result<SampleRun, Trap> {
        let t_max = sample.spikes.len();
        let mut run = SampleRun {
            outputs: Vec::with_capacity(t_max),
            spikes: 0,
            packets: 0,
        };
        let mut packets: Vec<Packet> = Vec::new();
        let mut res = StepResult::default();
        for t in 0..t_max {
            packets.clear();
            for &ch in &sample.spikes[t] {
                packets.extend(self.compiled.config.input_map[ch as usize].iter().copied());
            }
            self.step_into(&packets, &mut res, &mut run)?;
        }
        Ok(run)
    }

    /// Run one dense-valued sample (BCI binned rates — FP input mode).
    pub fn run_values(&mut self, sample: &DenseSample) -> Result<SampleRun, Trap> {
        let mut run = SampleRun {
            outputs: Vec::with_capacity(sample.values.len()),
            spikes: 0,
            packets: 0,
        };
        let mut packets: Vec<Packet> = Vec::new();
        let mut res = StepResult::default();
        for row in &sample.values {
            packets.clear();
            for (ch, &v) in row.iter().enumerate() {
                if v == 0.0 {
                    continue; // zero bins carry no information: stay sparse
                }
                for tpl in &self.compiled.config.input_map[ch] {
                    let mut p = *tpl;
                    p.payload = F16::from_f32(v).0;
                    packets.push(p);
                }
            }
            self.step_into(&packets, &mut res, &mut run)?;
        }
        Ok(run)
    }

    fn step_into(
        &mut self,
        packets: &[Packet],
        res: &mut StepResult,
        run: &mut SampleRun,
    ) -> Result<(), Trap> {
        self.chip.step_into(packets, res)?;
        run.spikes += res.spikes;
        run.packets += res.packets_routed;
        let mut row = vec![0.0f32; self.n_outputs];
        for h in &res.outputs {
            if let Some(&k) = self.compiled.readout.get(&(h.cc, h.nc, h.neuron)) {
                row[k] = F16(h.value).to_f32();
            }
        }
        run.outputs.push(row);
        Ok(())
    }

    /// Inject per-output-neuron errors and trigger the on-chip learning
    /// update (one Learn sweep in the next FIRE stage).
    pub fn learn_step(&mut self, errors: &[f32]) -> Result<(), Trap> {
        assert_eq!(errors.len(), self.compiled.error_map.len());
        let mut packets = Vec::with_capacity(errors.len());
        for (k, &e) in errors.iter().enumerate() {
            let mut p = self.compiled.error_map[k];
            p.payload = F16::from_f32(e).0;
            packets.push(p);
        }
        // deliver errors (INTEG) and run a FIRE stage (Learn events fire
        // because the head cores are configured with `learn = true`)
        self.chip.step(&packets)?;
        Ok(())
    }

    /// Zero all dynamic state (membrane, currents, adaptation, learning
    /// accumulators, errors) and put the wake sets back to sleep —
    /// between samples. Weights and parameters survive. Fails with a
    /// [`Trap`] if a compiled core layout addresses memory outside its
    /// NC (a compiler bug, surfaced instead of panicking).
    pub fn reset_state(&mut self) -> Result<(), Trap> {
        self.chip.flush_packets();
        // one shared zero buffer, grown to the largest region — this
        // runs before every sample, so no per-core allocations
        let mut zeros: Vec<u16> = Vec::new();
        for k in 0..self.compiled.cores.len() {
            let core = &self.compiled.cores[k];
            let (cc, nc, l) = (core.cc, core.nc, core.layout);
            // [cur, params) — currents + membrane
            let n = (l.params - l.cur) as usize;
            // [adapt, itof) — adaptation, acc counters, errors
            let n2 = (l.itof - l.adapt) as usize;
            if zeros.len() < n.max(n2) {
                zeros.resize(n.max(n2), 0);
            }
            self.chip.poke(cc, nc, l.cur, &zeros[..n])?;
            self.chip.poke(cc, nc, l.adapt, &zeros[..n2])?;
        }
        Ok(())
    }

    /// Read back a weight region (host monitoring path) — used by tests
    /// and the learning demo to show weights actually moved.
    pub fn peek_weights(&self, core_idx: usize, n: usize) -> Result<Vec<f32>, Trap> {
        let core = &self.compiled.cores[core_idx];
        Ok(self
            .chip
            .peek(core.cc, core.nc, core.layout.weights, n)?
            .into_iter()
            .map(|w| F16(w).to_f32())
            .collect())
    }
}

// ---------------------------------------------------------------------
// Multi-chip lockstep deployment.
// ---------------------------------------------------------------------

/// One parity's staging cells, indexed `[dst][src]`.
type StageCells = Vec<Vec<Mutex<Vec<Packet>>>>;

/// Host-side inter-die packet staging: `stage[parity][dst][src]` holds
/// the packets die `src` minted during a step of the given parity, to be
/// delivered to die `dst` in the next step. Double-buffering by step
/// parity means one barrier per step is enough: writers fill the other
/// parity while readers drain their own, and each (dst, src) cell has
/// exactly one writer and one reader per step.
struct Bridge {
    stage: [StageCells; 2],
    /// Parity of the next lockstep step.
    parity: usize,
}

impl Bridge {
    fn new(n: usize) -> Bridge {
        let mk = || {
            (0..n)
                .map(|_| (0..n).map(|_| Mutex::new(Vec::new())).collect())
                .collect()
        };
        Bridge {
            stage: [mk(), mk()],
            parity: 0,
        }
    }

    fn clear(&mut self) {
        for half in &mut self.stage {
            for row in half {
                for cell in row {
                    cell.get_mut().unwrap().clear();
                }
            }
        }
    }
}

/// One die's contribution to a lockstep run.
#[derive(Clone, Debug, Default)]
struct ChipRun {
    /// Host outputs per timestep (die-local CC coordinates).
    outputs: Vec<Vec<HostOutput>>,
    spikes: u64,
    packets: u64,
    /// Bridge packets this die staged per destination die.
    remote: Vec<u64>,
}

fn host_trap(msg: &str) -> Trap {
    Trap {
        pc: 0,
        msg: msg.to_string(),
    }
}

/// N dies of one sharded model, stepped in lockstep.
///
/// The run loop spawns one std thread per die. Each timestep, every die
/// drains its inbound bridge cells (packets from lower-numbered dies are
/// delivered *before* its own pending spikes, packets from higher dies
/// and host inputs after — reproducing the single-die ascending-source
/// delivery order), steps its [`Chip`], stages the step's
/// [`StepResult::egress`] for the destination dies, and meets the others
/// at a barrier. State reset, learning, and activity aggregation mirror
/// the single-die [`Deployment`] surface so the API layer can treat both
/// uniformly.
pub struct MultiChipDeployment {
    pub chips: Vec<Chip>,
    pub compiled: Arc<ShardedCompiled>,
    bridge: Bridge,
    /// Cumulative per-edge bridge traffic: `bridge_packets[src][dst]`
    /// counts the packets die `src` staged for die `dst` since
    /// deployment (the measured counterpart of the compiler's
    /// `cut_traffic` estimate and the fast backend's
    /// [`ChipActivity::remote_packets`]).
    bridge_packets: Vec<Vec<u64>>,
}

impl MultiChipDeployment {
    /// Configure one fresh chip per die (INIT stage on every die).
    pub fn new(compiled: Arc<ShardedCompiled>) -> Result<MultiChipDeployment, Trap> {
        if compiled.chips.is_empty() {
            return Err(host_trap("sharded image carries zero dies"));
        }
        let mut chips = Vec::with_capacity(compiled.chips.len());
        for image in &compiled.chips {
            let mut chip = Chip::new(compiled.data_words.max(64));
            chip.configure(&image.config)?;
            chips.push(chip);
        }
        Ok(MultiChipDeployment {
            bridge: Bridge::new(chips.len()),
            bridge_packets: vec![vec![0; chips.len()]; chips.len()],
            chips,
            compiled,
        })
    }

    pub fn num_chips(&self) -> usize {
        self.chips.len()
    }

    /// Cumulative per-edge bridge traffic, `[src][dst]`. The diagonal is
    /// always zero (a die never bridges to itself), and the total equals
    /// the aggregate [`ChipActivity::remote_packets`].
    pub fn bridge_traffic(&self) -> &[Vec<u64>] {
        &self.bridge_packets
    }

    /// Run one spike-train sample across all dies.
    pub fn run_spikes(&mut self, sample: &SpikeSample) -> Result<SampleRun, Trap> {
        let t_max = sample.spikes.len();
        let mut by_chip = vec![vec![Vec::new(); t_max]; self.chips.len()];
        for (t, active) in sample.spikes.iter().enumerate() {
            for &ch in active {
                for (chip, tpl) in &self.compiled.input_map[ch as usize] {
                    by_chip[*chip][t].push(*tpl);
                }
            }
        }
        self.run_bridged(&by_chip, t_max)
    }

    /// Run one dense-valued sample (FP input mode) across all dies.
    pub fn run_values(&mut self, sample: &DenseSample) -> Result<SampleRun, Trap> {
        let t_max = sample.values.len();
        let mut by_chip = vec![vec![Vec::new(); t_max]; self.chips.len()];
        for (t, row) in sample.values.iter().enumerate() {
            for (ch, &v) in row.iter().enumerate() {
                if v == 0.0 {
                    continue; // zero bins carry no information: stay sparse
                }
                for (chip, tpl) in &self.compiled.input_map[ch] {
                    let mut p = *tpl;
                    p.payload = F16::from_f32(v).0;
                    by_chip[*chip][t].push(p);
                }
            }
        }
        self.run_bridged(&by_chip, t_max)
    }

    /// Inject per-output errors on the head die(s) and run one lockstep
    /// learning sweep — the multi-die equivalent of
    /// [`Deployment::learn_step`].
    pub fn learn_step(&mut self, errors: &[f32]) -> Result<(), Trap> {
        assert_eq!(errors.len(), self.compiled.error_map.len());
        let mut by_chip = vec![vec![Vec::new(); 1]; self.chips.len()];
        for (k, &e) in errors.iter().enumerate() {
            let (chip, tpl) = self.compiled.error_map[k];
            let mut p = tpl;
            p.payload = F16::from_f32(e).0;
            by_chip[chip][0].push(p);
        }
        self.run_lockstep(&by_chip, 1, false)?;
        Ok(())
    }

    /// Zero all dynamic state on every die and drop in-flight bridge
    /// packets — between samples. Weights and parameters survive.
    pub fn reset_state(&mut self) -> Result<(), Trap> {
        for chip in &mut self.chips {
            chip.flush_packets();
        }
        self.bridge.clear();
        let mut zeros: Vec<u16> = Vec::new();
        for (chip_idx, core) in &self.compiled.cores {
            let (cc, nc, l) = (core.cc, core.nc, core.layout);
            let n = (l.params - l.cur) as usize;
            let n2 = (l.itof - l.adapt) as usize;
            if zeros.len() < n.max(n2) {
                zeros.resize(n.max(n2), 0);
            }
            let chip = &mut self.chips[*chip_idx];
            chip.poke(cc, nc, l.cur, &zeros[..n])?;
            chip.poke(cc, nc, l.adapt, &zeros[..n2])?;
        }
        Ok(())
    }

    /// Aggregate activity across dies: event counters sum; `timesteps`
    /// is the lockstep step count (every die steps together), not the
    /// per-die sum, so energy/throughput math sees wall-clock steps.
    pub fn activity(&self) -> ChipActivity {
        let mut total = ChipActivity::default();
        for chip in &self.chips {
            let a = chip.activity();
            total.nc.add(&a.nc);
            total.dt_reads += a.dt_reads;
            total.it_reads += a.it_reads;
            total.activations += a.activations;
            total.packets += a.packets;
            total.link_traversals += a.link_traversals;
            total.remote_packets += a.remote_packets;
            total.timesteps = total.timesteps.max(a.timesteps);
        }
        total
    }

    /// Per-die activity (per-die vs aggregate metrics in the docs).
    pub fn activity_per_chip(&self) -> Vec<ChipActivity> {
        self.chips.iter().map(|c| c.activity()).collect()
    }

    fn run_bridged(
        &mut self,
        inputs: &[Vec<Vec<Packet>>],
        t_max: usize,
    ) -> Result<SampleRun, Trap> {
        let runs = self.run_lockstep(inputs, t_max, true)?;
        let mut run = SampleRun {
            outputs: Vec::with_capacity(t_max),
            spikes: 0,
            packets: 0,
        };
        for cr in &runs {
            run.spikes += cr.spikes;
            run.packets += cr.packets;
        }
        for t in 0..t_max {
            let mut row = vec![0.0f32; self.compiled.n_outputs];
            for (i, cr) in runs.iter().enumerate() {
                for h in &cr.outputs[t] {
                    if let Some(&k) =
                        self.compiled.chips[i].readout.get(&(h.cc, h.nc, h.neuron))
                    {
                        row[k] = F16(h.value).to_f32();
                    }
                }
            }
            run.outputs.push(row);
        }
        Ok(run)
    }

    /// The lockstep core: one thread per die, one barrier per timestep.
    /// `inputs[die][t]` are host packets injected into that die at step
    /// `t`. On a trap, every thread exits at the same barrier round so
    /// nobody is left waiting; the first trap wins.
    fn run_lockstep(
        &mut self,
        inputs: &[Vec<Vec<Packet>>],
        t_max: usize,
        collect: bool,
    ) -> Result<Vec<ChipRun>, Trap> {
        let n = self.chips.len();
        debug_assert_eq!(inputs.len(), n);
        let start_parity = self.bridge.parity;
        let barrier = Barrier::new(n);
        let failed = AtomicBool::new(false);
        let bridge = &self.bridge;
        let results: Vec<(ChipRun, Option<Trap>)> = std::thread::scope(|sc| {
            let mut handles = Vec::new();
            for (i, (chip, chip_inputs)) in
                self.chips.iter_mut().zip(inputs.iter()).enumerate()
            {
                let barrier = &barrier;
                let failed = &failed;
                // threads return (run, trap) rather than Result so the
                // per-edge bridge counts a die staged *before* trapping
                // are still booked — keeping the bridge matrix equal to
                // the chips' own egress counters even across failures
                handles.push(sc.spawn(move || {
                    let mut out = ChipRun {
                        remote: vec![0; n],
                        ..ChipRun::default()
                    };
                    let mut res = StepResult::default();
                    let mut pre: Vec<Packet> = Vec::new();
                    let mut post: Vec<Packet> = Vec::new();
                    let mut err: Option<Trap> = None;
                    for t in 0..t_max {
                        let parity = (start_parity + t) & 1;
                        if err.is_none() {
                            // A panic escaping past `barrier.wait()` would
                            // leave the other dies waiting forever, so the
                            // step body is unwind-caught and converted into
                            // the same trap path a chip fault takes (this
                            // also absorbs the lock-poisoning panics a
                            // peer's panic can induce).
                            let step = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| -> Result<(), Trap> {
                                    // Inbound bridge packets: lower-numbered
                                    // dies land before this die's own pending
                                    // spikes, higher-numbered dies and host
                                    // inputs after — the single-die
                                    // ascending-source order.
                                    pre.clear();
                                    post.clear();
                                    for src in 0..n {
                                        let mut cell =
                                            bridge.stage[parity][i][src].lock().unwrap();
                                        if src < i {
                                            pre.append(&mut cell);
                                        } else if src > i {
                                            post.append(&mut cell);
                                        }
                                    }
                                    post.extend_from_slice(&chip_inputs[t]);
                                    chip.step_ext(&pre, &post, &mut res)?;
                                    out.spikes += res.spikes;
                                    out.packets += res.packets_routed;
                                    if collect {
                                        out.outputs.push(res.outputs.clone());
                                    }
                                    for p in &res.egress {
                                        if let RouteMode::Remote { chip: dst, x, y } =
                                            p.mode
                                        {
                                            out.remote[dst as usize] += 1;
                                            bridge.stage[parity ^ 1][dst as usize][i]
                                                .lock()
                                                .unwrap()
                                                .push(Packet {
                                                    mode: RouteMode::Unicast { x, y },
                                                    ..*p
                                                });
                                        }
                                    }
                                    Ok(())
                                }),
                            );
                            match step {
                                Ok(Ok(())) => {}
                                Ok(Err(e)) => {
                                    err = Some(e);
                                    failed.store(true, Ordering::SeqCst);
                                }
                                Err(_) => {
                                    err = Some(host_trap("chip worker panicked"));
                                    failed.store(true, Ordering::SeqCst);
                                }
                            }
                        }
                        barrier.wait();
                        if failed.load(Ordering::SeqCst) {
                            break;
                        }
                    }
                    (out, err)
                }));
            }
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        // the step body is unwind-caught, so a join
                        // failure is a harness bug; report it with an
                        // empty (zero-remote) run
                        (ChipRun::default(), Some(host_trap("chip worker panicked")))
                    })
                })
                .collect()
        });
        self.bridge.parity = (start_parity + t_max) & 1;
        // book every die's per-edge bridge counters — including packets a
        // die staged before trapping — so the bridge matrix stays equal
        // to the chips' aggregate egress counters across failures
        let mut runs = Vec::with_capacity(n);
        let mut first_err = None;
        for (i, (cr, err)) in results.into_iter().enumerate() {
            for (dst, &c) in cr.remote.iter().enumerate() {
                self.bridge_packets[i][dst] += c;
            }
            match err {
                Some(e) => first_err = first_err.or(Some(e)),
                None => runs.push(cr),
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(runs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{self, Options};
    use crate::datasets::SpikeSample;
    use crate::model;

    /// A hand-buildable 2-layer net: 4 inputs → 3 LIF → 2 readout.
    fn tiny_net() -> (model::NetDef, Vec<Vec<f32>>) {
        let mut net = model::NetDef::new("tiny", 5);
        net.layers.push(model::Layer::Input { size: 4 });
        net.layers.push(model::Layer::Fc {
            input: 4,
            output: 3,
            neuron: model::NeuronModel::Lif { tau: 0.5, vth: 0.9 },
        });
        net.layers.push(model::Layer::Fc {
            input: 3,
            output: 2,
            neuron: model::NeuronModel::Readout { tau: 0.5 },
        });
        // input->hidden: channel i drives neuron i%3 strongly
        let mut w1 = vec![0.0f32; 4 * 3];
        for i in 0..4 {
            w1[i * 3 + i % 3] = 1.0;
        }
        // hidden->readout: neuron 0,1 -> out 0; neuron 2 -> out 1
        let w2 = vec![0.6, 0.0, 0.6, 0.0, 0.0, 0.6];
        (net, vec![vec![], w1, w2])
    }

    fn deploy(net: &model::NetDef, weights: &[Vec<f32>], learning: bool) -> Deployment {
        let r = compiler::compile(
            net,
            weights,
            &Options {
                learning,
                sa_iters: 200,
                ..Default::default()
            },
        )
        .unwrap();
        Deployment::new(r.compiled).unwrap()
    }

    #[test]
    fn end_to_end_spike_flow_reaches_readout() {
        let (net, weights) = tiny_net();
        let mut d = deploy(&net, &weights, false);
        // drive channel 0 every step: hidden neuron 0 fires, readout 0
        // integrates (2-step pipeline latency: t spike -> t+1 hidden
        // fires -> t+2 readout sees it)
        let sample = SpikeSample {
            spikes: vec![vec![0u16]; 6],
            labels: vec![0],
        };
        let run = d.run_spikes(&sample).unwrap();
        assert!(run.spikes > 0, "hidden layer never fired");
        let summed = run.summed();
        assert!(
            summed[0] > summed[1],
            "readout 0 should dominate: {summed:?}"
        );
    }

    #[test]
    fn reset_state_silences_the_chip() {
        let (net, weights) = tiny_net();
        let mut d = deploy(&net, &weights, false);
        let sample = SpikeSample {
            spikes: vec![vec![0u16, 1, 2, 3]; 4],
            labels: vec![0],
        };
        d.run_spikes(&sample).unwrap();
        d.reset_state().unwrap();
        // with no input, a reset chip must produce zero readout
        let quiet = SpikeSample {
            spikes: vec![vec![]; 3],
            labels: vec![0],
        };
        let run = d.run_spikes(&quiet).unwrap();
        assert_eq!(run.spikes, 0);
        assert!(run.summed().iter().all(|&v| v == 0.0), "{:?}", run.summed());
    }

    #[test]
    fn weights_survive_reset() {
        let (net, weights) = tiny_net();
        let mut d = deploy(&net, &weights, false);
        let before = d.peek_weights(0, 6).unwrap();
        d.reset_state().unwrap();
        assert_eq!(before, d.peek_weights(0, 6).unwrap());
        assert!(before.iter().any(|&w| w != 0.0));
    }

    #[test]
    fn srnn_recurrence_sustains_activity() {
        // recurrent weights keep the hidden layer firing after input stops
        let mut net = model::NetDef::new("rec", 8);
        net.layers.push(model::Layer::Input { size: 2 });
        net.layers.push(model::Layer::Recurrent {
            input: 2,
            size: 4,
            neuron: model::NeuronModel::Lif { tau: 0.9, vth: 0.5 },
        });
        net.layers.push(model::Layer::Fc {
            input: 4,
            output: 1,
            neuron: model::NeuronModel::Readout { tau: 0.9 },
        });
        // strong input + strong self-excitation
        let mut w1 = vec![0.0f32; (2 + 4) * 4];
        for i in 0..2 {
            w1[i * 4 + i] = 1.0; // input i -> hidden i
        }
        for j in 0..4 {
            w1[(2 + j) * 4 + (j + 1) % 4] = 0.8; // ring recurrence
        }
        let w2 = vec![0.5; 4];
        let mut d = deploy(&net, &vec![vec![], w1, w2], false);
        // one input burst at t=0 only
        let mut spikes = vec![vec![]; 8];
        spikes[0] = vec![0u16, 1];
        let run = d
            .run_spikes(&SpikeSample { spikes, labels: vec![0] })
            .unwrap();
        // ring should keep spiking well past the input burst
        assert!(run.spikes >= 4, "recurrence died: {} spikes", run.spikes);
    }

    #[test]
    fn on_chip_learning_moves_head_weights() {
        let net = model::bci_net(2);
        let n_in = 2 * 8;
        let mut w = Vec::new();
        w.push(vec![]);
        // sparse blobs
        let mut w1 = vec![0.0f32; 128 * 16];
        for t in 0..16 {
            for k in 0..8 {
                w1[((t * 8 + k) % 128) * 16 + t] = 0.3;
            }
        }
        w.push(w1);
        let mut w2 = vec![0.0f32; 16 * 16];
        for t in 0..16 {
            w2[((t * 3) % 16) * 16 + t] = 1.5; // strong enough to relay spikes
        }
        w.push(w2);
        w.push(vec![0.05f32; n_in * 4]);
        let mut d = deploy(&net, &w, true);

        // find the head core (layer 3)
        let head = d
            .compiled
            .cores
            .iter()
            .position(|c| c.parts.iter().any(|p| p.0 == 3))
            .unwrap();
        let before = d.peek_weights(head, 8).unwrap();
        // run a real dense sample so layer-2 spikes reach the head and
        // charge its presynaptic accumulators, then inject errors
        let s = crate::datasets::bci::sample(0, 0, &mut crate::util::Rng::new(3));
        let run = d.run_values(&s).unwrap();
        assert!(run.spikes > 0, "no spikes reached the head");
        d.learn_step(&[0.5, -0.5, 0.25, -0.25]).unwrap();
        let after = d.peek_weights(head, 8).unwrap();
        assert_ne!(before, after, "learning did not touch the head weights");
    }
}
