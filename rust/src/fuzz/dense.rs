//! Golden dense reference: a direct [`NetDef`] interpreter that
//! bypasses partitioning, placement, codegen and the NoC entirely —
//! every neuron of every layer is simulated every step, straight from
//! the model description and the f32 weight blobs.
//!
//! It reproduces the engine's arithmetic *exactly*: weights and
//! parameters are quantized through [`F16::from_f32`] once at
//! construction, membrane updates use the single-rounding
//! [`F16::mul_add`] the `diff.f` ALU op performs, and synaptic
//! accumulation uses the `locacc.f` FP16 add. On the generator's
//! exactness grid (see [`crate::model::gen`]) the accumulation order
//! cannot affect any value, so a compiled engine — any placement, any
//! shard count — must produce bit-identical readout rows. A mismatch is
//! a routing/codegen bug by construction, never FP noise.
//!
//! Timing model (mirrors the chip scheduler):
//! * host events injected at step `t` integrate at step `t`;
//! * spikes minted at step `t` integrate at step `t + 1`;
//! * skip spikes minted at `t` over a `delay = d` edge integrate at
//!   step `t + 1 + d` (held in the minting CC's delay line);
//! * the learning step delivers the final stream step's spikes, stores
//!   the host error vector, then runs the learn sweep.

use crate::model::{gen::Stream, Layer, NetDef, NeuronModel, Skip};
use crate::util::F16;

/// Branch time constants baked into the DH-LIF parameter block by
/// codegen (heterogeneous per branch, not taken from the model).
const BRANCH_TAUS: [f32; 8] = [0.2, 0.5, 0.8, 0.95, 0.3, 0.6, 0.9, 0.99];

/// The learning rate codegen bakes into `params[4]`.
const LEARNING_RATE: f32 = 0.02;

#[derive(Clone, Copy)]
enum SimKind {
    /// Full connection; `branches > 1` for DH-LIF row banks.
    Fc,
    /// Extended-input fold: rows `0..n_in` forward, `n_in..n_in+n` self.
    Recurrent,
    /// Only nonzero blob entries connect.
    Sparse,
}

#[derive(Clone, Copy)]
struct Delivery {
    /// Destination layer as a sim index (layer index − 1).
    dest: usize,
    /// Weight row at the destination.
    axon: usize,
}

struct Sim {
    kind: SimKind,
    model: NeuronModel,
    n_in: usize,
    n: usize,
    branches: usize,
    /// FP16-quantized weights, logical row-major `[rows][n]`.
    w: Vec<F16>,
    /// Sparse connection mask (empty for dense kinds): the engine only
    /// materializes nonzero f32 blob entries as synapses.
    conn: Vec<bool>,
    /// Accumulated currents, one bank of `n` per branch.
    cur: Vec<F16>,
    vmem: Vec<F16>,
    /// ALIF threshold offset (`n`) or DH-LIF branch state
    /// (`branches · n`).
    adapt: Vec<F16>,
    /// Learning head: per-upstream-axon spike counters.
    acc: Vec<u32>,
    /// Learning head: per-neuron error slots.
    err: Vec<F16>,
}

impl Sim {
    fn rows(&self) -> usize {
        match self.kind {
            SimKind::Fc => self.branches * self.n_in,
            SimKind::Recurrent => self.n_in + self.n,
            SimKind::Sparse => self.n_in,
        }
    }
}

/// The interpreter. Construct once per case; state persists across
/// [`DenseRef::run`] and [`DenseRef::learn`] like a deployed chip's.
pub struct DenseRef {
    layers: Vec<Sim>,
    skips: Vec<Skip>,
    learning: bool,
    lr: F16,
    dense_input: bool,
    /// Deliveries due at each absolute step.
    pending: Vec<Vec<Delivery>>,
    steps_run: usize,
}

impl DenseRef {
    pub fn new(
        net: &NetDef,
        weights: &[Vec<f32>],
        learning: bool,
    ) -> Result<DenseRef, String> {
        let mut layers = Vec::new();
        match net.layers.first() {
            Some(Layer::Input { .. }) => {}
            _ => return Err("first layer must be Input".into()),
        }
        for (li, layer) in net.layers.iter().enumerate().skip(1) {
            let blob = weights
                .get(li)
                .ok_or_else(|| format!("missing weight blob for layer {li}"))?;
            layers.push(build_sim(li, layer, blob)?);
        }
        if layers.is_empty() {
            return Err("net has no connection layers".into());
        }
        if learning {
            let head = layers.last_mut().expect("non-empty");
            head.acc = vec![0; head.n_in];
            head.err = vec![F16::ZERO; head.n];
        }
        let dense_input = matches!(net.layers[1], Layer::Sparse { .. });
        Ok(DenseRef {
            layers,
            skips: net.skips.clone(),
            learning,
            lr: F16::from_f32(LEARNING_RATE),
            dense_input,
            pending: Vec::new(),
            steps_run: 0,
        })
    }

    /// Simulate the full stream; returns one readout row per step
    /// (zeros where the head emitted nothing — matching the engine's
    /// default row).
    pub fn run(&mut self, stream: &Stream) -> Vec<Vec<f32>> {
        match stream {
            Stream::Dense(_) => assert!(
                self.dense_input,
                "dense stream into a spike-input first layer"
            ),
            Stream::Spikes(_) => assert!(
                !self.dense_input,
                "spike stream into a dense-input (Sparse) first layer"
            ),
        }
        let steps = stream.steps();
        let mut rows = Vec::with_capacity(steps);
        for t in 0..steps {
            self.deliver_due(t);
            match stream {
                Stream::Spikes(s) => self.inject_spikes(&s[t]),
                Stream::Dense(v) => self.inject_dense(&v[t]),
            }
            rows.push(self.fire(t));
        }
        self.steps_run = steps;
        rows
    }

    /// One on-chip learning step after the stream: deliver the final
    /// step's spikes (they land in the learn step's INTEG, bumping the
    /// head's ACC counters), store the error vector, then apply the
    /// `fire_learn_head` sweep `w[u][i] -= itof(ACC[u]) · ERR[i] · lr`.
    pub fn learn(&mut self, errors: &[f32]) {
        assert!(self.learning, "learn() on a non-learning reference");
        self.deliver_due(self.steps_run);
        let head = self.layers.last_mut().expect("non-empty");
        assert_eq!(errors.len(), head.n, "error vector width");
        for (i, &e) in errors.iter().enumerate() {
            head.err[i] = F16::from_f32(e);
        }
        let (n_in, n) = (head.n_in, head.n);
        for i in 0..n {
            let el = head.err[i].mul(self.lr);
            for u in 0..n_in {
                let c = head.acc[u].min(255) as f32;
                let delta = F16::from_f32(c).mul(el);
                head.w[u * n + i] = head.w[u * n + i].sub(delta);
            }
        }
    }

    /// The head's logical weight matrix (`[n_in][n]`, row-major) as
    /// f32 — comparable against `peek_weights` of a compiled engine.
    pub fn head_weights(&self) -> Vec<f32> {
        let head = self.layers.last().expect("non-empty");
        head.w.iter().map(|w| w.to_f32()).collect()
    }

    pub fn n_outputs(&self) -> usize {
        self.layers.last().expect("non-empty").n
    }

    // -- internals ----------------------------------------------------

    fn slot(&mut self, step: usize) -> &mut Vec<Delivery> {
        if self.pending.len() <= step {
            self.pending.resize_with(step + 1, Vec::new);
        }
        &mut self.pending[step]
    }

    fn deliver_due(&mut self, t: usize) {
        if self.pending.len() <= t {
            return;
        }
        let due = std::mem::take(&mut self.pending[t]);
        for d in due {
            self.deliver(d);
        }
    }

    fn deliver(&mut self, d: Delivery) {
        let is_head = self.learning && d.dest == self.layers.len() - 1;
        let l = &mut self.layers[d.dest];
        match l.kind {
            SimKind::Fc | SimKind::Recurrent => {
                debug_assert!(d.axon < l.rows());
                for j in 0..l.n {
                    let w = l.w[d.axon * l.n + j];
                    l.cur[j] = l.cur[j].add(w);
                }
            }
            SimKind::Sparse => {
                for j in 0..l.n {
                    if l.conn[d.axon * l.n + j] {
                        let w = l.w[d.axon * l.n + j];
                        l.cur[j] = l.cur[j].add(w);
                    }
                }
            }
        }
        if is_head {
            l.acc[d.axon] += 1;
        }
    }

    fn inject_spikes(&mut self, channels: &[u16]) {
        let l = &mut self.layers[0];
        for &ch in channels {
            let ch = ch as usize;
            match l.kind {
                SimKind::Fc => {
                    // one packet per branch: channel `ch` feeds branch
                    // `b` through weight row `b·n_in + ch` into that
                    // branch's current bank
                    for b in 0..l.branches {
                        let row = b * l.n_in + ch;
                        for j in 0..l.n {
                            let w = l.w[row * l.n + j];
                            l.cur[b * l.n + j] = l.cur[b * l.n + j].add(w);
                        }
                    }
                }
                SimKind::Recurrent => {
                    for j in 0..l.n {
                        let w = l.w[ch * l.n + j];
                        l.cur[j] = l.cur[j].add(w);
                    }
                }
                SimKind::Sparse => unreachable!("guarded in run()"),
            }
        }
    }

    fn inject_dense(&mut self, values: &[f32]) {
        let l = &mut self.layers[0];
        for (ch, &v) in values.iter().enumerate() {
            // the coordinator skips exact-zero bins at injection
            if v == 0.0 {
                continue;
            }
            let scale = F16::from_f32(v);
            for j in 0..l.n {
                if l.conn[ch * l.n + j] {
                    let w = l.w[ch * l.n + j].mul(scale);
                    l.cur[j] = l.cur[j].add(w);
                }
            }
        }
    }

    /// FIRE every neuron of every layer; returns the head readout row
    /// and schedules minted spikes.
    fn fire(&mut self, t: usize) -> Vec<f32> {
        let last = self.layers.len() - 1;
        let mut row = vec![0.0f32; self.layers[last].n];
        let mut minted: Vec<(usize, usize)> = Vec::new();
        for (idx, l) in self.layers.iter_mut().enumerate() {
            for j in 0..l.n {
                match l.model {
                    NeuronModel::Lif { .. } => {
                        let (tau, vth) = f16_tau_vth(&l.model);
                        let v2 = tau.mul_add(l.vmem[j], l.cur[j]);
                        l.cur[j] = F16::ZERO;
                        if ge(v2, vth) {
                            minted.push((idx, j));
                            l.vmem[j] = F16::ZERO;
                        } else {
                            l.vmem[j] = v2;
                        }
                    }
                    NeuronModel::Alif { .. } => {
                        let (tau, vth) = f16_tau_vth(&l.model);
                        let (rho, beta) = f16_rho_beta(&l.model);
                        let v2 = tau.mul_add(l.vmem[j], l.cur[j]);
                        l.cur[j] = F16::ZERO;
                        let mut a1 = l.adapt[j].mul(rho);
                        let th = vth.add(a1);
                        if ge(v2, th) {
                            minted.push((idx, j));
                            l.vmem[j] = F16::ZERO;
                            a1 = a1.add(beta);
                        } else {
                            l.vmem[j] = v2;
                        }
                        l.adapt[j] = a1;
                    }
                    NeuronModel::DhLif { branches, .. } => {
                        let (tau, vth) = f16_tau_vth(&l.model);
                        let mut v2 = tau.mul_add(l.vmem[j], F16::ZERO);
                        for b in 0..branches {
                            let tb = F16::from_f32(BRANCH_TAUS[b % BRANCH_TAUS.len()]);
                            let b2 = tb.mul_add(l.adapt[b * l.n + j], l.cur[b * l.n + j]);
                            l.adapt[b * l.n + j] = b2;
                            l.cur[b * l.n + j] = F16::ZERO;
                            v2 = v2.add(b2);
                        }
                        if ge(v2, vth) {
                            minted.push((idx, j));
                            l.vmem[j] = F16::ZERO;
                        } else {
                            l.vmem[j] = v2;
                        }
                    }
                    NeuronModel::Readout { tau } => {
                        let tau = F16::from_f32(tau);
                        let v2 = tau.mul_add(l.vmem[j], l.cur[j]);
                        l.cur[j] = F16::ZERO;
                        l.vmem[j] = v2;
                        if idx == last {
                            row[j] = v2.to_f32();
                        }
                    }
                    NeuronModel::Psum => unreachable!("rejected in new()"),
                }
            }
        }
        for (idx, j) in minted {
            self.schedule(idx, j, t);
        }
        row
    }

    /// Route one minted spike: forward edge, recurrent self-edge, and
    /// any skip edges sourced at this layer.
    fn schedule(&mut self, idx: usize, j: usize, t: usize) {
        let li = idx + 1;
        let n_in = self.layers[idx].n_in;
        let recurrent = matches!(self.layers[idx].kind, SimKind::Recurrent);
        if idx + 1 < self.layers.len() {
            self.slot(t + 1).push(Delivery { dest: idx + 1, axon: j });
        }
        if recurrent {
            self.slot(t + 1).push(Delivery { dest: idx, axon: n_in + j });
        }
        let skips: Vec<Skip> =
            self.skips.iter().copied().filter(|s| s.from == li).collect();
        for s in skips {
            let due = t + 1 + s.delay();
            self.slot(due).push(Delivery { dest: s.to - 1, axon: j });
        }
    }
}

fn build_sim(li: usize, layer: &Layer, blob: &[f32]) -> Result<Sim, String> {
    let (kind, n_in, n, branches, model) = match layer {
        Layer::Fc { input, output, neuron } => {
            let branches = match neuron {
                NeuronModel::DhLif { branches, .. } => *branches,
                _ => 1,
            };
            (SimKind::Fc, *input, *output, branches, *neuron)
        }
        Layer::Recurrent { input, size, neuron } => {
            (SimKind::Recurrent, *input, *size, 1, *neuron)
        }
        Layer::Sparse { input, output, neuron, .. } => {
            (SimKind::Sparse, *input, *output, 1, *neuron)
        }
        l => return Err(format!("layer {li}: unsupported kind {l:?}")),
    };
    if matches!(model, NeuronModel::Psum) {
        return Err(format!("layer {li}: explicit Psum neurons unsupported"));
    }
    let rows = match kind {
        SimKind::Fc => branches * n_in,
        SimKind::Recurrent => n_in + n,
        SimKind::Sparse => n_in,
    };
    if blob.len() != rows * n {
        return Err(format!(
            "layer {li}: weight blob has {} entries, expected {}",
            blob.len(),
            rows * n
        ));
    }
    let w: Vec<F16> = blob.iter().map(|&x| F16::from_f32(x)).collect();
    let conn = if matches!(kind, SimKind::Sparse) {
        blob.iter().map(|&x| x != 0.0).collect()
    } else {
        Vec::new()
    };
    let adapt_len = match model {
        NeuronModel::Alif { .. } => n,
        NeuronModel::DhLif { .. } => branches * n,
        _ => 0,
    };
    Ok(Sim {
        kind,
        model,
        n_in,
        n,
        branches,
        w,
        conn,
        cur: vec![F16::ZERO; branches * n],
        vmem: vec![F16::ZERO; n],
        adapt: vec![F16::ZERO; adapt_len],
        acc: Vec::new(),
        err: Vec::new(),
    })
}

fn f16_tau_vth(m: &NeuronModel) -> (F16, F16) {
    let (tau, vth) = match *m {
        NeuronModel::Lif { tau, vth } => (tau, vth),
        NeuronModel::Alif { tau, vth, .. } => (tau, vth),
        NeuronModel::DhLif { tau_soma, vth, .. } => (tau_soma, vth),
        NeuronModel::Readout { tau } => (tau, 1.0),
        NeuronModel::Psum => (0.0, 1.0),
    };
    (F16::from_f32(tau), F16::from_f32(vth))
}

fn f16_rho_beta(m: &NeuronModel) -> (F16, F16) {
    match *m {
        NeuronModel::Alif { rho, beta, .. } => {
            (F16::from_f32(rho), F16::from_f32(beta))
        }
        _ => (F16::ZERO, F16::ZERO),
    }
}

/// The FIRE programs spike on `NOT (v < threshold)`.
fn ge(a: F16, b: F16) -> bool {
    !(a.to_f32() < b.to_f32())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::NetDef;

    fn two_layer_net() -> (NetDef, Vec<Vec<f32>>) {
        let lif = NeuronModel::Lif { tau: 0.5, vth: 1.0 };
        let mut net = NetDef::new("dense-ref-unit", 4);
        net.layers.push(Layer::Input { size: 2 });
        net.layers.push(Layer::Fc { input: 2, output: 2, neuron: lif });
        net.layers.push(Layer::Fc {
            input: 2,
            output: 1,
            neuron: NeuronModel::Readout { tau: 0.5 },
        });
        // channel 0 drives neuron 0 at exactly vth; neuron 1 never fires
        let w1 = vec![1.0, 0.0, 0.0, 0.25];
        let w2 = vec![0.5, 0.25];
        (net, vec![vec![], w1, w2])
    }

    #[test]
    fn spike_reaches_readout_two_steps_later() {
        let (net, w) = two_layer_net();
        let mut r = DenseRef::new(&net, &w, false).unwrap();
        let stream = Stream::Spikes(vec![vec![0], vec![], vec![], vec![]]);
        let rows = r.run(&stream);
        // t=0: hidden 0 hits vth and fires; t=1 the spike integrates at
        // the readout, which emits 0.5 that same step's FIRE
        assert_eq!(rows[0], vec![0.0]);
        assert_eq!(rows[1], vec![0.5]);
        // decay afterwards: 0.25, 0.125
        assert_eq!(rows[2], vec![0.25]);
        assert_eq!(rows[3], vec![0.125]);
    }

    #[test]
    fn threshold_is_inclusive() {
        let (net, w) = two_layer_net();
        let mut r = DenseRef::new(&net, &w, false).unwrap();
        // v == vth must spike (the ALU branches on NOT lt)
        let rows = r.run(&Stream::Spikes(vec![vec![0], vec![]]));
        assert_eq!(rows[1], vec![0.5], "exact-threshold spike must fire");
    }

    #[test]
    fn learn_sweep_moves_head_weights() {
        let (net, w) = two_layer_net();
        let mut r = DenseRef::new(&net, &w, true).unwrap();
        let _ = r.run(&Stream::Spikes(vec![vec![0], vec![], vec![]]));
        let before = r.head_weights();
        r.learn(&[1.0]);
        let after = r.head_weights();
        // hidden 0 fired once → ACC[0] = 1 → w[0] moves by 1·1.0·lr;
        // hidden 1 never fired → w[1] untouched
        assert!(after[0] < before[0]);
        assert_eq!(after[1], before[1]);
    }
}
