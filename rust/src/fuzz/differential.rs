//! The multi-engine differential oracle.
//!
//! Each generated case ([`crate::model::gen::generate`]) runs through
//! every execution engine the repo has — the golden dense reference
//! ([`DenseRef`]), the event-driven wake-set chip, the same image with
//! `scan_all` sweeping, the statically-scheduled engine (a
//! [`crate::compiler::schedule`] visit program over the same image,
//! pre-flighted by the schedule checker), and `compile_sharded` at
//! 2/4/8 dies under both
//! [`ShardStrategy`] cuts — and every readout row (plus, for learning
//! cases, the post-update head weight matrix) is compared with exact
//! f32 equality. The generator keeps all values on an exactness grid,
//! so the first mismatch is a routing/codegen bug, never FP noise; the
//! report pins it to (engine, step, output neuron) with the single-die
//! (cc, nc, neuron) coordinates and a seed-replay repro line.
//!
//! A typed compiler refusal (e.g. `TooManyCores` on a cut the placement
//! cannot satisfy) is counted per engine, not treated as a failure: the
//! oracle distinguishes "this engine declines the case" from "this
//! engine computes the wrong answer". Sharded cases additionally run on
//! the pipelined multi-die engine (bounded run-ahead) against the same
//! compiled image, so the bridge's step-indexed fusion is fuzzed too.

use std::sync::Arc;

use crate::chip::StepSchedule;
use crate::compiler::{self, Compiled, CompileError, ShardStrategy};
use crate::coordinator::{Deployment, MultiChipDeployment, StepEvents, StepRow};
use crate::fuzz::dense::DenseRef;
use crate::model::gen::{generate, validate_options, GenCase, GenSpec, Stream};
use crate::model::{axon_pad, Layer, NetDef, NeuronModel};
use crate::nc::Trap;
use crate::util::json::Json;

/// Die counts every shardable case is exercised at.
pub const SHARD_COUNTS: [usize; 3] = [2, 4, 8];

/// One engine-vs-reference mismatch, localized as far as the compiled
/// metadata allows.
#[derive(Clone, Debug)]
pub struct Divergence {
    pub engine: String,
    /// The replay seed ([`GenCase::seed`]).
    pub seed: u64,
    /// Timestep of the first bad readout row (`None` for post-learning
    /// weight mismatches and engine faults).
    pub step: Option<usize>,
    /// Output-neuron index of the first mismatch (readout rows) or
    /// head-matrix column (weight mismatches).
    pub output: Option<usize>,
    pub expected: f32,
    pub got: f32,
    /// (cc, nc, local neuron) of the diverging readout neuron on the
    /// single-die reference image, when one compiled.
    pub location: Option<(usize, u8, u16)>,
    pub detail: String,
}

impl Divergence {
    /// The command line that regenerates and re-runs exactly this case.
    pub fn repro(&self) -> String {
        format!("cargo run --release -- fuzz --replay {}", self.seed)
    }

    pub fn to_json(&self) -> Json {
        let loc = match self.location {
            Some((cc, nc, n)) => {
                Json::Str(format!("cc{cc}/nc{nc}/neuron{n}"))
            }
            None => Json::Null,
        };
        Json::obj()
            .set("engine", self.engine.as_str())
            .set("seed", self.seed)
            .set(
                "step",
                self.step.map(|s| Json::Int(s as i64)).unwrap_or(Json::Null),
            )
            .set(
                "output",
                self.output
                    .map(|k| Json::Int(k as i64))
                    .unwrap_or(Json::Null),
            )
            .set("expected", self.expected)
            .set("got", self.got)
            .set("location", loc)
            .set("detail", self.detail.as_str())
            .set("repro", self.repro())
    }
}

/// A compiler refusing to build one engine for one case.
#[derive(Clone, Debug)]
pub struct Refusal {
    pub engine: String,
    pub seed: u64,
    pub msg: String,
}

/// How one engine fared on one case.
#[derive(Clone, Debug)]
pub enum Outcome {
    /// Every readout row (and the head weights, for learning cases)
    /// matched the dense reference bit-exactly.
    Match,
    /// The compiler declined this (net, engine) pairing with a typed
    /// error.
    Refused(String),
    Diverged(Divergence),
}

#[derive(Clone, Debug)]
pub struct EngineOutcome {
    pub engine: String,
    pub outcome: Outcome,
}

/// All engines' outcomes for one generated case.
#[derive(Clone, Debug)]
pub struct CaseReport {
    pub seed: u64,
    pub learning: bool,
    /// Candidates the generator redrew before this case.
    pub rejected: usize,
    pub engines: Vec<EngineOutcome>,
}

impl CaseReport {
    pub fn divergences(&self) -> impl Iterator<Item = &Divergence> {
        self.engines.iter().filter_map(|e| match &e.outcome {
            Outcome::Diverged(d) => Some(d),
            _ => None,
        })
    }
}

/// Aggregate over a fuzz run.
#[derive(Clone, Debug, Default)]
pub struct FuzzReport {
    /// Cases the generator produced (excludes generator give-ups).
    pub cases: usize,
    /// Seeds where the retry budget ran out
    /// ([`CompileError::Generator`]).
    pub generator_rejects: usize,
    pub learning_cases: usize,
    /// Engine runs that completed and matched.
    pub engine_matches: usize,
    pub refusals: Vec<Refusal>,
    pub divergences: Vec<Divergence>,
}

impl FuzzReport {
    pub fn ok(&self) -> bool {
        self.divergences.is_empty()
    }

    fn absorb(&mut self, case: CaseReport) {
        self.cases += 1;
        if case.learning {
            self.learning_cases += 1;
        }
        for e in case.engines {
            match e.outcome {
                Outcome::Match => self.engine_matches += 1,
                Outcome::Refused(msg) => self.refusals.push(Refusal {
                    engine: e.engine,
                    seed: case.seed,
                    msg,
                }),
                Outcome::Diverged(d) => self.divergences.push(d),
            }
        }
    }

    pub fn to_json(&self) -> Json {
        let refusals: Vec<Json> = self
            .refusals
            .iter()
            .map(|r| {
                Json::obj()
                    .set("engine", r.engine.as_str())
                    .set("seed", r.seed)
                    .set("msg", r.msg.as_str())
            })
            .collect();
        let divergences: Vec<Json> =
            self.divergences.iter().map(|d| d.to_json()).collect();
        Json::obj()
            .set("cases", self.cases as u64)
            .set("generator_rejects", self.generator_rejects as u64)
            .set("learning_cases", self.learning_cases as u64)
            .set("engine_matches", self.engine_matches as u64)
            .set("refusals", refusals)
            .set("divergences", divergences)
    }
}

/// Run `cases` sequentially-seeded cases through the full oracle.
pub fn run_fuzz(spec: &GenSpec, cases: usize, base_seed: u64) -> FuzzReport {
    let mut report = FuzzReport::default();
    for i in 0..cases {
        let seed = base_seed.wrapping_add(i as u64);
        match generate(spec, seed) {
            Ok(case) => report.absorb(run_case(spec, &case)),
            Err(_) => report.generator_rejects += 1,
        }
    }
    report
}

/// Regenerate one seed and run it through the oracle (`--replay`).
pub fn replay(spec: &GenSpec, seed: u64) -> Result<CaseReport, CompileError> {
    let case = generate(spec, seed)?;
    Ok(run_case(spec, &case))
}

/// One case through every engine.
pub fn run_case(spec: &GenSpec, case: &GenCase) -> CaseReport {
    let mut report = CaseReport {
        seed: case.seed,
        learning: case.learning,
        rejected: case.rejected,
        engines: Vec::new(),
    };
    let mut dense = match DenseRef::new(&case.net, &case.weights, case.learning) {
        Ok(d) => d,
        Err(msg) => {
            report.engines.push(EngineOutcome {
                engine: "dense-ref".into(),
                outcome: Outcome::Refused(msg),
            });
            return report;
        }
    };
    let golden = dense.run(&case.stream);
    let golden_w = if case.learning {
        dense.learn(&case.errors);
        Some(dense.head_weights())
    } else {
        None
    };

    // The oracle runs the static verifier itself as a pre-flight stage,
    // so a malformed image surfaces as a localized divergence instead of
    // an opaque compile refusal (and is never executed).
    let mut opts = validate_options(case.learning, spec);
    opts.verify = false;

    // single-die engines share one compiled image: the wake-set run and
    // the scan-every-column run differ only in the chip's scan flag
    match compiler::compile(&case.net, &case.weights, &opts) {
        Ok(rep) => {
            let vr = compiler::verify::verify(&rep.compiled, &case.net, case.learning);
            if !vr.ok() {
                report.engines.push(EngineOutcome {
                    engine: "verify".into(),
                    outcome: Outcome::Diverged(preflight("verify", case.seed, &vr)),
                });
                return report;
            }
            let image = Arc::new(rep.compiled);
            let locs = readout_locs(&image);
            for (name, scan) in [("wake", false), ("scan-all", true)] {
                let outcome = match Deployment::from_image(image.clone()) {
                    Ok(mut d) => {
                        d.chip.scan_all = scan;
                        drive(
                            name,
                            &mut Engine::Single(d),
                            case,
                            &golden,
                            golden_w.as_deref(),
                            &locs,
                        )
                    }
                    Err(t) => Outcome::Diverged(fault(name, case.seed, &t)),
                };
                report.engines.push(EngineOutcome {
                    engine: name.into(),
                    outcome,
                });
            }
            // fourth single-die column: the statically-scheduled engine
            // over the same image, its visit program computed here and
            // pre-flighted by the schedule checker
            let prog = compiler::schedule::schedule(&image, &case.net, case.learning);
            let sr = compiler::verify::verify_schedule(&prog, &image, &case.net, case.learning);
            let outcome = if sr.ok() {
                match Deployment::from_image(image.clone()) {
                    Ok(mut d) => {
                        d.chip.schedule = StepSchedule::Static(Arc::new(prog));
                        drive(
                            "scheduled",
                            &mut Engine::Single(d),
                            case,
                            &golden,
                            golden_w.as_deref(),
                            &locs,
                        )
                    }
                    Err(t) => Outcome::Diverged(fault("scheduled", case.seed, &t)),
                }
            } else {
                Outcome::Diverged(preflight("scheduled", case.seed, &sr))
            };
            report.engines.push(EngineOutcome { engine: "scheduled".into(), outcome });
        }
        Err(e) => {
            for name in ["wake", "scan-all", "scheduled"] {
                report.engines.push(EngineOutcome {
                    engine: name.into(),
                    outcome: Outcome::Refused(e.to_string()),
                });
            }
        }
    }

    for chips in SHARD_COUNTS {
        for strategy in [ShardStrategy::Contiguous, ShardStrategy::MinCut] {
            let name = format!("sharded-{chips}-{strategy}");
            let pname = format!("pipelined-{chips}-{strategy}");
            match compiler::compile_sharded(&case.net, &case.weights, &{
                let mut o = opts.clone();
                o.strategy = strategy;
                o
            }, chips)
            {
                Ok(rep) => {
                    let vr = compiler::verify::verify_sharded(
                        &rep.sharded,
                        &case.net,
                        case.learning,
                    );
                    if vr.ok() {
                        // sequential reference and the pipelined
                        // run-ahead engine share one compiled image, so
                        // any mismatch between the two columns is a
                        // bridge-fusion bug, never a compile difference
                        let image = Arc::new(rep.sharded);
                        let outcome = match MultiChipDeployment::new(image.clone()) {
                            Ok(m) => drive(
                                &name,
                                &mut Engine::Multi(m),
                                case,
                                &golden,
                                golden_w.as_deref(),
                                &[],
                            ),
                            Err(t) => Outcome::Diverged(fault(&name, case.seed, &t)),
                        };
                        report.engines.push(EngineOutcome {
                            engine: name,
                            outcome,
                        });
                        let outcome =
                            match MultiChipDeployment::pipelined(image, 2) {
                                Ok(m) => drive(
                                    &pname,
                                    &mut Engine::Multi(m),
                                    case,
                                    &golden,
                                    golden_w.as_deref(),
                                    &[],
                                ),
                                Err(t) => {
                                    Outcome::Diverged(fault(&pname, case.seed, &t))
                                }
                            };
                        report.engines.push(EngineOutcome {
                            engine: pname,
                            outcome,
                        });
                    } else {
                        let d = Outcome::Diverged(preflight(&name, case.seed, &vr));
                        report.engines.push(EngineOutcome {
                            engine: name,
                            outcome: d.clone(),
                        });
                        report.engines.push(EngineOutcome {
                            engine: pname,
                            outcome: d,
                        });
                    }
                }
                Err(e) => {
                    report.engines.push(EngineOutcome {
                        engine: name,
                        outcome: Outcome::Refused(e.to_string()),
                    });
                    report.engines.push(EngineOutcome {
                        engine: pname,
                        outcome: Outcome::Refused(e.to_string()),
                    });
                }
            }
        }
    }
    report
}

/// Compile the single-die engine in the pre-fix bug-compat mode
/// (`Options::aliased_sparse_fanout`) and diff its forward pass against
/// the dense reference. Returns the first divergence — `None` when the
/// case never exercises a spike-fed sparse destination (or the compiler
/// refuses it), in which case the aliasing bug has nothing to bite.
pub fn aliased_divergence(spec: &GenSpec, case: &GenCase) -> Option<Divergence> {
    let mut dense = DenseRef::new(&case.net, &case.weights, false).ok()?;
    let golden = dense.run(&case.stream);
    let mut opts = validate_options(false, spec);
    opts.aliased_sparse_fanout = true;
    let rep = compiler::compile(&case.net, &case.weights, &opts).ok()?;
    let image = Arc::new(rep.compiled);
    let locs = readout_locs(&image);
    let d = Deployment::from_image(image).ok()?;
    match drive(
        "aliased",
        &mut Engine::Single(d),
        case,
        &golden,
        None,
        &locs,
    ) {
        Outcome::Diverged(d) => Some(d),
        _ => None,
    }
}

// ---------------------------------------------------------------------
// Engine plumbing.
// ---------------------------------------------------------------------

enum Engine {
    Single(Deployment),
    Multi(MultiChipDeployment),
}

impl Engine {
    fn step(&mut self, ev: StepEvents<'_>) -> Result<StepRow, Trap> {
        match self {
            Engine::Single(d) => d.step_events(ev),
            Engine::Multi(m) => m.step_events(ev),
        }
    }

    fn learn(&mut self, errors: &[f32]) -> Result<(), Trap> {
        match self {
            Engine::Single(d) => d.learn_step(errors),
            Engine::Multi(m) => m.learn_step(errors),
        }
    }

    /// The head's logical weight matrix read back from the die(s) —
    /// comparable against [`DenseRef::head_weights`].
    fn head_weights(
        &self,
        net: &NetDef,
        weights: &[Vec<f32>],
    ) -> Result<Vec<f32>, Trap> {
        match self {
            Engine::Single(d) => head_weights_via(
                net,
                weights,
                d.compiled.cores.iter().enumerate(),
                |k, n| d.peek_weights(k, n),
            ),
            Engine::Multi(m) => head_weights_via(
                net,
                weights,
                m.compiled.cores.iter().enumerate().map(|(k, (_, c))| (k, c)),
                |k, n| m.peek_weights(k, n),
            ),
        }
    }
}

/// A static-verifier rejection, shaped as a divergence so the fuzz
/// report pins it with the same seed-replay machinery.
fn preflight(
    engine: &str,
    seed: u64,
    vr: &crate::compiler::verify::VerifyReport,
) -> Divergence {
    let first = vr
        .errors
        .first()
        .map_or_else(|| vr.summary(), |e| e.to_string());
    Divergence {
        engine: engine.into(),
        seed,
        step: None,
        output: None,
        expected: 0.0,
        got: 0.0,
        location: None,
        detail: format!("pre-flight verify: {first}"),
    }
}

fn fault(engine: &str, seed: u64, t: &Trap) -> Divergence {
    Divergence {
        engine: engine.into(),
        seed,
        step: None,
        output: None,
        expected: 0.0,
        got: 0.0,
        location: None,
        detail: format!("engine fault: {}", t.msg),
    }
}

/// Invert the single-die readout map: output index → (cc, nc, neuron).
fn readout_locs(image: &Compiled) -> Vec<Option<(usize, u8, u16)>> {
    let mut locs = vec![None; image.readout.len()];
    for (&(cc, nc, neuron), &k) in &image.readout {
        if let Some(slot) = locs.get_mut(k) {
            *slot = Some((cc, nc, neuron));
        }
    }
    locs
}

/// Step the engine through the case's stream comparing every readout
/// row against the golden rows, then (for learning cases) apply the
/// learning step and compare the head weight matrix.
fn drive(
    name: &str,
    eng: &mut Engine,
    case: &GenCase,
    golden: &[Vec<f32>],
    golden_w: Option<&[f32]>,
    locs: &[Option<(usize, u8, u16)>],
) -> Outcome {
    for (t, want) in golden.iter().enumerate() {
        let ev = match &case.stream {
            Stream::Spikes(s) => StepEvents::Spikes(&s[t]),
            Stream::Dense(v) => StepEvents::Dense(&v[t]),
        };
        let sr = match eng.step(ev) {
            Ok(sr) => sr,
            Err(trap) => return Outcome::Diverged(fault(name, case.seed, &trap)),
        };
        for (k, &w) in want.iter().enumerate() {
            let got = sr.row.get(k).copied().unwrap_or(0.0);
            if got != w {
                return Outcome::Diverged(Divergence {
                    engine: name.into(),
                    seed: case.seed,
                    step: Some(t),
                    output: Some(k),
                    expected: w,
                    got,
                    location: locs.get(k).copied().flatten(),
                    detail: format!(
                        "readout row mismatch at step {t}, output {k}"
                    ),
                });
            }
        }
    }
    let Some(want_w) = golden_w else {
        return Outcome::Match;
    };
    if let Err(trap) = eng.learn(&case.errors) {
        return Outcome::Diverged(fault(name, case.seed, &trap));
    }
    let got_w = match eng.head_weights(&case.net, &case.weights) {
        Ok(w) => w,
        Err(trap) => return Outcome::Diverged(fault(name, case.seed, &trap)),
    };
    let n_out = case.errors.len();
    for (idx, (&w, &g)) in want_w.iter().zip(got_w.iter()).enumerate() {
        if w != g {
            return Outcome::Diverged(Divergence {
                engine: name.into(),
                seed: case.seed,
                step: None,
                output: Some(idx % n_out.max(1)),
                expected: w,
                got: g,
                location: None,
                detail: format!(
                    "post-learning head weight mismatch at row {}, column {}",
                    idx / n_out.max(1),
                    idx % n_out.max(1)
                ),
            });
        }
    }
    Outcome::Match
}

/// Weight-region words one core part occupies (must mirror
/// `codegen::core_weights` exactly — the peek offsets walk this).
fn part_words(
    net: &NetDef,
    weights: &[Vec<f32>],
    li: usize,
    n_base: usize,
    count: usize,
) -> usize {
    let pad = axon_pad(net, li);
    match &net.layers[li] {
        Layer::Fc { input, neuron, .. } => {
            let branches = match neuron {
                NeuronModel::DhLif { branches, .. } => *branches,
                _ => 1,
            };
            (pad + input * branches) * count
        }
        Layer::Recurrent { input, size, .. } => (pad + input + size) * count,
        Layer::Sparse { input, output, .. } => {
            let blob = &weights[li];
            let mut nz = 0usize;
            for u in 0..*input {
                for j in 0..count {
                    if blob[u * output + n_base + j] != 0.0 {
                        nz += 1;
                    }
                }
            }
            nz
        }
        _ => 0,
    }
}

/// Reassemble the head's logical weight matrix from per-core weight
/// regions: each hosting core stores `(pad + n_in)` rows × `count`
/// columns for its resident head neurons, after any co-located earlier
/// parts' weights.
fn head_weights_via<'a, I, F>(
    net: &NetDef,
    weights: &[Vec<f32>],
    cores: I,
    mut peek: F,
) -> Result<Vec<f32>, Trap>
where
    I: Iterator<Item = (usize, &'a crate::compiler::codegen::CoreMeta)>,
    F: FnMut(usize, usize) -> Result<Vec<f32>, Trap>,
{
    let head_li = net.layers.len() - 1;
    let (n_in, n_out) = match &net.layers[head_li] {
        Layer::Fc { input, output, .. } => (*input, *output),
        other => {
            return Err(Trap {
                pc: 0,
                msg: format!("learning head is not Fc: {other:?}"),
            })
        }
    };
    let pad = axon_pad(net, head_li);
    let mut w = vec![0.0f32; n_in * n_out];
    for (k, core) in cores {
        let mut off = 0usize;
        for &(li, n_base, count, _) in &core.parts {
            if li == head_li {
                let region = peek(k, off + (pad + n_in) * count)?;
                for u in 0..n_in {
                    for j in 0..count {
                        w[u * n_out + n_base + j] =
                            region[off + (pad + u) * count + j];
                    }
                }
            }
            off += part_words(net, weights, li, n_base, count);
        }
    }
    Ok(w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_cases_match_across_all_engines() {
        let spec = GenSpec::default();
        let report = run_fuzz(&spec, 12, 100);
        assert!(report.cases >= 10, "generator gave up too often");
        assert!(
            report.ok(),
            "divergences: {:#?}\nrepro: {}",
            report.divergences,
            report.divergences[0].repro()
        );
        assert!(report.engine_matches > 0);
    }

    #[test]
    fn sharded_scale_cases_run_on_multi_die_engines_only() {
        let spec = GenSpec::sharded_scale();
        let case = generate(&spec, 3).unwrap();
        let report = run_case(&spec, &case);
        // one die cannot hold the net: the single-die engines refuse …
        for name in ["wake", "scan-all", "scheduled"] {
            let e = report
                .engines
                .iter()
                .find(|e| e.engine == name)
                .unwrap();
            assert!(
                matches!(e.outcome, Outcome::Refused(_)),
                "{name} should refuse a past-one-die net"
            );
        }
        // … and at least one sharded engine runs it and matches, on
        // both the sequential reference and the pipelined engine
        for prefix in ["sharded", "pipelined"] {
            let matched = report
                .engines
                .iter()
                .filter(|e| e.engine.starts_with(prefix))
                .filter(|e| matches!(e.outcome, Outcome::Match))
                .count();
            assert!(matched > 0, "no {prefix} engine matched: {report:#?}");
        }
        assert_eq!(report.divergences().count(), 0, "{report:#?}");
    }

    #[test]
    fn report_json_renders() {
        let spec = GenSpec::default();
        let report = run_fuzz(&spec, 2, 7);
        let s = report.to_json().render();
        assert!(s.contains("\"cases\":2"), "{s}");
        assert!(s.contains("divergences"), "{s}");
    }
}
