//! Differential fuzzing subsystem.
//!
//! Two halves, matching the two halves of the bug-hunting loop:
//!
//! * [`crate::model::gen`] — the seeded, parameterized net/workload
//!   generator. A [`GenSpec`] describes a family of networks (layer
//!   kinds, widths, skip/recurrence/learning probabilities, input
//!   statistics) and `generate(spec, seed)` draws one compilable
//!   `(net, weights, stream)` case, deterministically per seed, with
//!   every value placed on an exactness grid so FP16 accumulation
//!   order cannot affect any result.
//! * [`differential`] — the multi-engine oracle. Each case runs on the
//!   [`dense::DenseRef`] golden interpreter (straight from the
//!   `NetDef`, no placement or codegen anywhere near it) and on every
//!   compiled engine: wake-set, scan-all, and 2/4/8-die sharded builds
//!   under both cut strategies. Rows must match with exact f32
//!   equality; the first mismatch is reported with (engine, step,
//!   output neuron), chip coordinates, and a `--replay <seed>` repro
//!   line.
//!
//! The subsystem exists because the sparse-destination fan-out
//! aliasing bug survived every example-based test in the repo: it only
//! bites when ≥ 2 distinct upstream axons hit a sparse destination
//! with different connection rows — a shape no hand-written workload
//! happened to pin. `Options::aliased_sparse_fanout` preserves the
//! broken encoding so [`differential::aliased_divergence`] can
//! demonstrate, forever, that the oracle catches it mechanically.
//!
//! CLI: `taibai fuzz --cases N --seed S [--max-neurons M] [--sharded]
//! [--aliased] [--replay SEED]`.

pub mod dense;
pub mod differential;

pub use crate::model::gen::{generate, GenCase, GenSpec, Stream};
pub use dense::DenseRef;
pub use differential::{
    aliased_divergence, replay, run_case, run_fuzz, CaseReport, Divergence,
    FuzzReport, Outcome,
};
