//! Fig 13d — the three Table II SNN benchmarks on TaiBai (fast analytic
//! mode; these nets are 10⁵–10⁶ neurons) vs the GPU-baseline model.
//! Paper: comparable accuracy, power ÷65–338, efficiency ×6–20; the
//! 13 %-firing-rate nets lose efficiency relative to the 8 % one, and
//! the multi-chip nets (PLIF, ResNet19) lose throughput to inter-chip
//! packets.

use taibai::bench::{f2, si, Table};
use taibai::chip::fast::{simulate, FastParams};
use taibai::energy::gpu::GpuModel;
use taibai::energy::EnergyModel;
use taibai::model;

fn main() {
    let em = EnergyModel::default();
    let gpu = GpuModel::default();
    let mut t = Table::new(&[
        "net", "rate", "chips", "TaiBai W", "GPU W", "power ratio",
        "TaiBai fps/W", "GPU fps/W", "eff ratio",
    ]);

    // paper §V-C.1: first model 8% firing rate, latter two 13%
    for (net, rate) in [
        (model::plif_net(), 0.08),
        (model::blocks5_net(), 0.13),
        (model::resnet19(), 0.13),
    ] {
        let mut p = FastParams::default();
        p.default_rate = rate;
        let r = simulate(&net, &p, &em);

        let flops = GpuModel::snn_step_flops(
            net.total_connections(),
            net.total_neurons() as u64,
        ) * net.timesteps as f64;
        // the GPU baseline batches 64 samples to amortize kernel
        // launches (the paper's pynvml measurements ran batched)
        let batch = 64.0;
        let launches = (net.layers.len() as u64) * 3 * net.timesteps as u64;
        let g = gpu.estimate(flops * batch, launches);
        let gpu_fps = batch / g.time_s;
        let gpu_eff = gpu_fps / g.power_w;

        t.row(&[
            net.name.clone(),
            format!("{:.0}%", rate * 100.0),
            format!("{}", r.chips),
            f2(r.power_w),
            f2(g.power_w),
            format!("{:.0}x", g.power_w / r.power_w),
            f2(r.fps_per_w),
            format!("{:.3}", gpu_eff),
            format!("{:.1}x", r.fps_per_w / gpu_eff),
        ]);
        // shape assertions (who wins, roughly by how much)
        assert!(g.power_w / r.power_w > 10.0, "{}: power win lost", net.name);
        assert!(r.fps_per_w > gpu_eff, "{}: efficiency win lost", net.name);
    }
    t.print();
    println!(
        "\n(paper Fig 13d: power reduced 65–338x, efficiency improved 6–20x; \
         SOP totals: plif={}, resnet19={})",
        si(simulate(&model::plif_net(), &FastParams::default(), &em).sops_per_sample as f64),
        si(simulate(&model::resnet19(), &FastParams::default(), &em).sops_per_sample as f64),
    );
}
