//! Fig 13d — the three Table II SNN benchmarks on TaiBai (analytic
//! backend; these nets are 10⁵–10⁶ neurons) vs the GPU-baseline model.
//! Paper: comparable accuracy, power ÷65–338, efficiency ×6–20; the
//! 13 %-firing-rate nets lose efficiency relative to the 8 % one, and
//! the multi-chip nets (PLIF, ResNet19) lose throughput to inter-chip
//! packets.

use taibai::api::{Backend, ExecOptions, FastParams, Sample, Taibai};
use taibai::bench::{f2, si, Table};
use taibai::energy::gpu::GpuModel;
use taibai::model::{self, Layer};

fn input_channels(net: &model::NetDef) -> usize {
    match net.layers.first() {
        Some(Layer::Input { size }) => *size,
        _ => 0,
    }
}

fn main() {
    let gpu = GpuModel::default();
    let mut t = Table::new(&[
        "net", "rate", "chips", "TaiBai W", "GPU W", "power ratio",
        "TaiBai fps/W", "GPU fps/W", "eff ratio",
    ]);

    // paper §V-C.1: first model 8% firing rate, latter two 13%;
    // the footer quotes SOP totals for the two nets the paper names
    let mut sop_notes: Vec<String> = Vec::new();
    for (net, rate, note_sops) in [
        (model::plif_net(), 0.08, true),
        (model::blocks5_net(), 0.13, false),
        (model::resnet19(), 0.13, true),
    ] {
        let channels = input_channels(&net);
        let timesteps = net.timesteps;
        let name = net.name.clone();
        let connections = net.total_connections();
        let neurons = net.total_neurons() as u64;
        let layers = net.layers.len() as u64;

        let mut session = Taibai::new(net)
            .rates(vec![rate]) // pin the input rate exactly
            .exec(ExecOptions {
                backend: Backend::Analytic,
                fast: FastParams {
                    default_rate: rate,
                    ..FastParams::default()
                },
                ..ExecOptions::default()
            })
            .build()
            .expect("analytic deploy");
        session
            .run(&Sample::poisson(channels, timesteps, rate, 42))
            .expect("analytic run");
        let m = session.metrics();

        let flops = GpuModel::snn_step_flops(connections, neurons) * timesteps as f64;
        // the GPU baseline batches 64 samples to amortize kernel
        // launches (the paper's pynvml measurements ran batched)
        let batch = 64.0;
        let launches = layers * 3 * timesteps as u64;
        let g = gpu.estimate(flops * batch, launches);
        let gpu_fps = batch / g.time_s;
        let gpu_eff = gpu_fps / g.power_w;

        t.row(&[
            name.clone(),
            format!("{:.0}%", rate * 100.0),
            format!("{}", m.chips),
            f2(m.power_w),
            f2(g.power_w),
            format!("{:.0}x", g.power_w / m.power_w),
            f2(m.fps_per_w),
            format!("{:.3}", gpu_eff),
            format!("{:.1}x", m.fps_per_w / gpu_eff),
        ]);
        // shape assertions (who wins, roughly by how much)
        assert!(g.power_w / m.power_w > 10.0, "{name}: power win lost");
        assert!(m.fps_per_w > gpu_eff, "{name}: efficiency win lost");
        if note_sops {
            sop_notes.push(format!("{name}={}", si(m.sops as f64)));
        }
    }
    t.print();
    println!(
        "\n(paper Fig 13d: power reduced 65–338x, efficiency improved 6–20x; \
         SOP totals: {})",
        sop_notes.join(", ")
    );
}
