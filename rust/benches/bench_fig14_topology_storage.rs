//! Fig 14 — efficiency of the network-topology representation: per
//! model, the cumulative storage of the four schemes (FC-unfolded
//! baseline → +decoupled conv → +parallel send → +incremental FC), plus
//! the ResNet18 skip-connection core comparison. Paper: 286–947×
//! reduction; ResNet18 cores at 70.3 % of the duplicate-core method.

use taibai::bench::Table;
use taibai::model;
use taibai::topology::storage::{skip_core_cost, storage, ALL_SCHEMES};

fn main() {
    let nets = [
        model::vgg16(),
        model::resnet18(),
        model::plif_net(),
        model::blocks5_net(),
        model::resnet19(),
    ];

    let mut t = Table::new(&[
        "model", "baseline MiB", "+conv decouple", "+parallel send",
        "+incremental FC (ours)", "reduction",
    ]);
    for net in &nets {
        let sizes: Vec<f64> = ALL_SCHEMES
            .iter()
            .map(|&s| storage(net, s).total_bits() as f64 / 8.0 / 1024.0 / 1024.0)
            .collect();
        let red = sizes[0] / sizes[3];
        t.row(&[
            net.name.clone(),
            format!("{:.1}", sizes[0]),
            format!("{:.1}", sizes[1]),
            format!("{:.2}", sizes[2]),
            format!("{:.2}", sizes[3]),
            format!("{red:.0}x"),
        ]);
        assert!(
            sizes.windows(2).all(|w| w[0] >= w[1]),
            "{}: schemes not monotone",
            net.name
        );
        // the paper's 286–947x band is for the wide-channel VGG/ResNet
        // class; thin nets (16-channel 5Blocks) reduce less since the
        // decoupling factor scales with cin*cout
        let floor = if net.name.contains("5Blocks") { 20.0 } else { 100.0 };
        assert!(red > floor, "{}: reduction {red:.0}x too small", net.name);
    }
    t.print();
    println!("\n(paper: storage reduced 286–947x vs the FC-unfolded baseline)");

    // skip connections: delayed-spike scheme vs relay/duplicate cores
    let net = model::resnet18();
    let (ours, dup) = skip_core_cost(&net, 2048);
    println!(
        "ResNet18 cores: ours {} vs duplicate-core {} = {:.1}% (paper: 70.3%)",
        ours,
        dup,
        ours as f64 / dup as f64 * 100.0
    );
}
