//! Table III — chip characteristics: capacity accounting and peak-rate
//! microbenchmarks on the behavioral model, printed next to the paper's
//! numbers.

use taibai::bench::{si, Table};
use taibai::energy::{dense_sop_activity, EnergyModel, CLOCK_HZ};
use taibai::noc::router::{inter_chip_cost, Mesh, CYCLES_PER_HOP};
use taibai::noc::{cc_id, MESH_H, MESH_W, NUM_CCS};
use taibai::topology::{RouteMode, NCS_PER_CC, MAX_FAN_IN};

fn main() {
    let em = EnergyModel::default();
    let mut t = Table::new(&["characteristic", "TaiBai (paper)", "this model"]);

    t.row(&["technology".into(), "28 nm".into(), "behavioral (28 nm-class constants)".into()]);
    t.row(&["clock".into(), "500 MHz".into(), format!("{} MHz", CLOCK_HZ / 1e6)]);
    t.row(&["cores".into(), "1056 (132 CC x 8 NC)".into(), format!("{} ({} CC x {} NC)", NUM_CCS * NCS_PER_CC, NUM_CCS, NCS_PER_CC)]);

    // neuron capacity: state words per neuron (v + I + params share) over
    // the NC memory budget
    let words_per_neuron = 4;
    let neurons = NUM_CCS * NCS_PER_CC * (taibai::nc::DEFAULT_DATA_WORDS / words_per_neuron / 32);
    t.row(&["neurons".into(), "264K".into(), si(neurons as f64)]);

    // synapse capacity: sparse mode (unique weights) vs conv multiplexing
    let weight_words = NUM_CCS * NCS_PER_CC * 24 * 1024;
    let conv_reuse = 36; // k^2 * typical spatial share
    t.row(&[
        "synapses".into(),
        "6.95M ~ 297M".into(),
        format!("{} ~ {}", si(weight_words as f64 / 3.2), si(weight_words as f64 * conv_reuse as f64 / 3.2)),
    ]);
    t.row(&["max fan-in/neuron".into(), "2K".into(), si(MAX_FAN_IN as f64)]);

    // intra-chip spike-event bandwidth: each router forwards one flit per
    // port per cycle when pipelined (5 ports: N/S/E/W/local)
    let per_router = CLOCK_HZ * 5.0;
    let intra = per_router * NUM_CCS as f64;
    t.row(&["intra-chip SE/s".into(), "322 GSE/s".into(), format!("{}SE/s", si(intra))]);

    // inter-chip: SerDes-limited through the 2*MESH_H edge proxies, one
    // packet per SERDES_CYCLES-deep pipe each
    let (_, lat) = inter_chip_cost(cc_id(0, 5), 1, cc_id(11, 5));
    let _ = lat;
    let serdes_rate =
        (2 * MESH_H) as f64 * CLOCK_HZ / taibai::noc::router::SERDES_CYCLES as f64;
    t.row(&["inter-chip SE/s".into(), "363 MSE/s".into(), format!("{}SE/s", si(serdes_rate))]);

    // peak SOPs: one LOCACC retires per NC per cycle at full pipeline
    // occupancy (the sustained *program* rate is ~4x lower; Table III
    // quotes the peak, which is what we reproduce)
    let gsops = NUM_CCS as f64 * NCS_PER_CC as f64 * CLOCK_HZ;
    t.row(&["peak SOPs".into(), "528 GSOPS".into(), format!("{}SOPS", si(gsops))]);

    // power at peak dense traffic
    let a = dense_sop_activity((gsops / 1000.0) as u64);
    let p = em.power_w(&a, (CLOCK_HZ / 1000.0) as u64);
    t.row(&["power".into(), "1.83 W".into(), format!("{p:.2} W")]);
    t.row(&["energy/SOP".into(), "2.61 pJ".into(), format!("{:.2} pJ", em.pj_per_sop(&a))]);
    t.row(&["bit width".into(), "16 (FP16/INT16)".into(), "16 (FP16/INT16)".into()]);

    t.print();

    // microbench: routing throughput of the mesh model itself
    let mut mesh = Mesh::new();
    let secs = taibai::bench::time(1, 3, || {
        for s in 0..NUM_CCS {
            mesh.route(s, RouteMode::Unicast { x: (s % MESH_W) as u8, y: 0 });
            mesh.route(s, RouteMode::Multicast { x0: 2, y0: 2, x1: 8, y1: 8 });
        }
    });
    println!(
        "\n[sim perf] mesh model: {:.1} Mpackets/s simulated",
        (2 * NUM_CCS) as f64 / secs / 1e6
    );
}
