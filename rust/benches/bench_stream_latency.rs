//! Stream-latency bench: per-push wall-clock of the event-driven
//! Session stream on the SHD workload (700 input channels, the widest
//! paper app).
//!
//! The serving story depends on `Stream::push` being cheap and scaling
//! with the events actually pushed, not with deployment size — the
//! streaming face of the wake-set sparsity claim. For input sparsity
//! levels 1%, 10%, and 50% it reports mean/max per-push wall-clock
//! (from the stream's own `LatencyStats` counters, measured inside
//! `stream_push`) and spikes per push.
//!
//! `--json <path>` writes the per-level measurements as machine-
//! readable perf JSON (`BENCH_stream.json` in CI, uploaded as an
//! artifact next to the wakeset and multichip JSONs so the streaming
//! perf trajectory is tracked across PRs).
//!
//! ```sh
//! cargo bench --bench bench_stream_latency              # full run
//! cargo bench --bench bench_stream_latency -- \
//!     --samples 1 --timesteps 20 --json BENCH_stream.json    # CI smoke
//! ```

use taibai::api::workloads::{Shd, Workload};
use taibai::api::{Backend, LatencyStats, StepEvents};
use taibai::bench::Table;
use taibai::util::cli::Args;
use taibai::util::json::Json;
use taibai::util::Rng;

const CHANNELS: usize = 700;

fn main() {
    let args = Args::from_env();
    let samples = args.usize("samples", 5);
    let timesteps = args.usize("timesteps", 100);
    let seed = args.u64("seed", 42);

    let w = Shd { dendrites: true };
    let mut session = w
        .session(Backend::Detailed, seed)
        .expect("compiling the SHD workload");
    println!(
        "SHD streaming deployment: {} cores; {samples} streams x {timesteps} pushes per level\n",
        session.info().used_cores
    );

    let mut t = Table::new(&[
        "input rate",
        "µs/push mean",
        "µs/push max",
        "spikes/push",
        "pushes",
    ]);
    let mut levels = Vec::new();
    let mut active: Vec<u16> = Vec::new();
    for &rate in &[0.01, 0.10, 0.50] {
        let mut rng = Rng::new(seed ^ (rate * 1000.0) as u64);
        let mut lat = LatencyStats::default();
        let mut spikes = 0u64;
        let mut pushes = 0u64;
        for _ in 0..samples {
            let mut stream = session.open_stream().expect("opening stream");
            for _ in 0..timesteps {
                active.clear();
                for ch in 0..CHANNELS {
                    if rng.chance(rate) {
                        active.push(ch as u16);
                    }
                }
                stream.push(StepEvents::Spikes(&active)).expect("push");
            }
            let rep = stream.finish().expect("finishing stream");
            lat.merge(&rep.latency);
            spikes += rep.spikes;
            pushes += rep.steps;
        }
        t.row(&[
            format!("{:>4.0}%", rate * 100.0),
            format!("{:.2}", lat.mean_us()),
            format!("{:.2}", lat.max_us()),
            format!("{:.1}", spikes as f64 / pushes.max(1) as f64),
            format!("{pushes}"),
        ]);
        levels.push(
            Json::obj()
                .set("input_rate", rate)
                .set("us_per_push_mean", lat.mean_us())
                .set("us_per_push_max", lat.max_us())
                .set("spikes_per_push", spikes as f64 / pushes.max(1) as f64)
                .set("pushes", pushes),
        );
    }
    t.print();

    if let Some(path) = args.get("json") {
        let doc = Json::obj()
            .set("bench", "stream_latency")
            .set("samples", samples)
            .set("timesteps", timesteps)
            .set("seed", seed)
            .set("used_cores", session.info().used_cores)
            .set("levels", Json::Arr(levels));
        std::fs::write(path, doc.render() + "\n").expect("writing perf JSON");
        println!("\nperf JSON written to {path}");
    }

    println!(
        "\nper-push cost tracks the events pushed (the wake-set sparsity win, \
         streaming edition) — the latency a SessionPool tenant sees per timestep."
    );
}
