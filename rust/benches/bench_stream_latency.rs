//! Stream-latency bench: per-push wall-clock of the event-driven
//! Session stream on the SHD workload (700 input channels, the widest
//! paper app).
//!
//! The serving story depends on `Stream::push` being cheap and scaling
//! with the events actually pushed, not with deployment size — the
//! streaming face of the wake-set sparsity claim. For input sparsity
//! levels 1%, 10%, and 50% it reports mean/max per-push wall-clock
//! (from the stream's own `LatencyStats` counters, measured inside
//! `stream_push`) and spikes per push — across three engine modes:
//!
//! * `wake-set`   — the dynamic wake-set stepper (baseline);
//! * `scheduled`  — the statically scheduled step engine (compile-time
//!   `VisitProgram` drains instead of wake-set bookkeeping);
//! * `pipelined`  — a 2-die shard on the pipelined multi-die stepper
//!   (depth 2, per-die visit programs installed), pushing through the
//!   same streaming face; each push drains to the step barrier, so this
//!   measures the pipeline's per-step floor, not its run-ahead ceiling.
//!
//! `--json <path>` writes the per-level measurements as machine-
//! readable perf JSON (`BENCH_stream.json` in CI, uploaded as an
//! artifact next to the wakeset and multichip JSONs so the streaming
//! perf trajectory is tracked across PRs).
//!
//! ```sh
//! cargo bench --bench bench_stream_latency              # full run
//! cargo bench --bench bench_stream_latency -- \
//!     --samples 1 --timesteps 20 --json BENCH_stream.json    # CI smoke
//! ```

use taibai::api::workloads::{Shd, Workload};
use taibai::api::{Backend, ExecOptions, LatencyStats, Session, StepEvents};
use taibai::bench::Table;
use taibai::util::cli::Args;
use taibai::util::json::Json;
use taibai::util::Rng;

const CHANNELS: usize = 700;

/// Push `samples` streams of `timesteps` random-sparsity steps per
/// level through one session, appending a table row and a JSON entry
/// per level.
fn measure_levels(
    mode: &str,
    session: &mut Session,
    samples: usize,
    timesteps: usize,
    seed: u64,
    t: &mut Table,
) -> Vec<Json> {
    let mut levels = Vec::new();
    let mut active: Vec<u16> = Vec::new();
    for &rate in &[0.01, 0.10, 0.50] {
        let mut rng = Rng::new(seed ^ (rate * 1000.0) as u64);
        let mut lat = LatencyStats::default();
        let mut spikes = 0u64;
        let mut pushes = 0u64;
        for _ in 0..samples {
            let mut stream = session.open_stream().expect("opening stream");
            for _ in 0..timesteps {
                active.clear();
                for ch in 0..CHANNELS {
                    if rng.chance(rate) {
                        active.push(ch as u16);
                    }
                }
                stream.push(StepEvents::Spikes(&active)).expect("push");
            }
            let rep = stream.finish().expect("finishing stream");
            lat.merge(&rep.latency);
            spikes += rep.spikes;
            pushes += rep.steps;
        }
        t.row(&[
            mode.to_string(),
            format!("{:>4.0}%", rate * 100.0),
            format!("{:.2}", lat.mean_us()),
            format!("{:.2}", lat.max_us()),
            format!("{:.1}", spikes as f64 / pushes.max(1) as f64),
            format!("{pushes}"),
        ]);
        levels.push(
            Json::obj()
                .set("mode", mode)
                .set("input_rate", rate)
                .set("us_per_push_mean", lat.mean_us())
                .set("us_per_push_max", lat.max_us())
                .set("spikes_per_push", spikes as f64 / pushes.max(1) as f64)
                .set("pushes", pushes),
        );
    }
    levels
}

fn main() {
    let args = Args::from_env();
    let samples = args.usize("samples", 5);
    let timesteps = args.usize("timesteps", 100);
    let seed = args.u64("seed", 42);

    let w = Shd { dendrites: true };
    let build = |x: ExecOptions| w.taibai(seed).exec(x).build();
    let mut session = build(ExecOptions::default()).expect("compiling the SHD workload");
    println!(
        "SHD streaming deployment: {} cores; {samples} streams x {timesteps} pushes per level\n",
        session.info().used_cores
    );

    let mut t = Table::new(&[
        "mode",
        "input rate",
        "µs/push mean",
        "µs/push max",
        "spikes/push",
        "pushes",
    ]);
    // wake-set baseline (the historical top-level "levels" JSON block)
    let levels = measure_levels("wake-set", &mut session, samples, timesteps, seed, &mut t);
    let mut modes = Vec::new();

    // statically scheduled single die (ROADMAP static-schedule rung)
    let mut scheduled = build(ExecOptions {
        schedule: true,
        ..ExecOptions::default()
    })
    .expect("compiling the scheduled SHD deployment");
    let sched_levels =
        measure_levels("scheduled", &mut scheduled, samples, timesteps, seed, &mut t);
    let sched_visits = scheduled.telemetry().sched;
    assert!(
        sched_visits.static_cc_visits > 0,
        "scheduled mode never used its visit program"
    );
    modes.push(
        Json::obj()
            .set("mode", "scheduled")
            .set("static_cc_visits", sched_visits.static_cc_visits)
            .set("levels", Json::Arr(sched_levels)),
    );

    // pipelined 2-die shard, per-die visit programs, streaming pushes
    let mut piped = build(ExecOptions {
        backend: Backend::Sharded { chips: 2 },
        schedule: true,
        sa_iters: 0,
        pipeline_depth: 2,
        ..ExecOptions::default()
    })
    .expect("compiling the pipelined SHD shard");
    let piped_levels =
        measure_levels("pipelined", &mut piped, samples, timesteps, seed, &mut t);
    let piped_tele = piped.telemetry();
    assert!(
        piped_tele.sched.static_cc_visits > 0,
        "pipelined stepper never used its per-die visit programs"
    );
    assert!(
        piped_tele.pipeline.is_some(),
        "pipelined mode must expose PipelineStats"
    );
    modes.push(
        Json::obj()
            .set("mode", "pipelined")
            .set("dies", 2)
            .set("depth", 2)
            .set("static_cc_visits", piped_tele.sched.static_cc_visits)
            .set("levels", Json::Arr(piped_levels)),
    );
    t.print();

    if let Some(path) = args.get("json") {
        let doc = Json::obj()
            .set("bench", "stream_latency")
            .set("samples", samples)
            .set("timesteps", timesteps)
            .set("seed", seed)
            .set("used_cores", session.info().used_cores)
            .set("levels", Json::Arr(levels))
            .set("modes", Json::Arr(modes));
        std::fs::write(path, doc.render() + "\n").expect("writing perf JSON");
        println!("\nperf JSON written to {path}");
    }

    println!(
        "\nper-push cost tracks the events pushed (the wake-set sparsity win, \
         streaming edition) — the latency a SessionPool tenant sees per timestep."
    );
}
