//! Multi-chip scaling bench: per-step wall-clock vs shard count.
//!
//! Two claims under measurement:
//! * forcing a single-die workload (SHD) onto 2 or 4 lockstep dies
//!   changes wall-clock (thread + bridge overhead vs per-die work
//!   shrinking) but **never** the readout — outputs are asserted
//!   bit-identical across die counts;
//! * a network that cannot compile on one die at all (> 1056 neuron
//!   cores) runs end-to-end at its natural die count.
//!
//! ```sh
//! cargo bench --bench bench_multichip_scaling              # full run
//! cargo bench --bench bench_multichip_scaling -- --samples 1   # CI smoke
//! ```

use std::time::Instant;

use taibai::api::workloads::{Shd, Workload};
use taibai::api::{Backend, Sample, Taibai};
use taibai::bench::Table;
use taibai::compiler::Objective;
use taibai::model;
use taibai::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let samples = args.usize("samples", 5);
    let seed = args.u64("seed", 42);

    let w = Shd { dendrites: true };
    let all = w.dataset(samples.max(1), seed);
    let data = &all[..samples.min(all.len())];
    let total_steps: usize = data.iter().map(|s| s.timesteps()).sum();

    let mut t = Table::new(&[
        "deployment",
        "dies",
        "cores",
        "ms/sample",
        "us/step",
        "spikes/sample",
    ]);

    // ---- SHD forced onto 1 / 2 / 4 dies ------------------------------
    let mut reference: Option<Vec<Vec<Vec<f32>>>> = None;
    for &chips in &[1usize, 2, 4] {
        let mut session = Taibai::new(w.net())
            .weights(w.weights(seed))
            .rates(w.rates())
            .sa_iters(0)
            .backend(Backend::Sharded { chips })
            .build()
            .expect("compiling SHD sharded");
        let mut spikes = 0u64;
        let mut outs = Vec::new();
        let start = Instant::now();
        for s in data {
            let r = session.run(s).expect("running SHD sample");
            spikes += r.spikes;
            outs.push(r.outputs);
        }
        let secs = start.elapsed().as_secs_f64();
        match &reference {
            None => reference = Some(outs),
            Some(r) => assert_eq!(
                r, &outs,
                "{chips}-die readout diverged from the 1-die reference"
            ),
        }
        t.row(&[
            "SHD".to_string(),
            format!("{}", session.info().chips),
            format!("{}", session.info().used_cores),
            format!("{:.3}", secs / data.len() as f64 * 1e3),
            format!("{:.1}", secs / total_steps.max(1) as f64 * 1e6),
            format!("{:.1}", spikes as f64 / data.len() as f64),
        ]);
    }

    // ---- over-capacity net at its natural die count ------------------
    let net = model::wide_fc_net(8, 600, 2, 4);
    let weights = model::wide_fc_weights(&net, seed);
    let mut session = Taibai::new(net)
        .weights(weights)
        .objective(Objective::Balanced(1))
        .merge(false)
        .sa_iters(0)
        .backend(Backend::Sharded { chips: 0 })
        .build()
        .expect("compiling the over-capacity net");
    let steps = 8usize;
    let probe = Sample::poisson(8, steps, 0.5, seed);
    let start = Instant::now();
    let r = session.run(&probe).expect("running the wide net");
    let secs = start.elapsed().as_secs_f64();
    assert!(r.spikes > 0, "wide net never spiked");
    t.row(&[
        "Wide-FC 1204c".to_string(),
        format!("{}", session.info().chips),
        format!("{}", session.info().used_cores),
        format!("{:.3}", secs * 1e3),
        format!("{:.1}", secs / steps as f64 * 1e6),
        format!("{:.1}", r.spikes as f64),
    ]);

    t.print();
    println!(
        "\nReadout rows are asserted bit-identical across die counts; the \
         wide net only exists beyond one die's 1056 cores."
    );
}
