//! Multi-chip scaling bench: per-step wall-clock, CC visits, and bridge
//! traffic vs shard count — plus the Contiguous-vs-MinCut cut-strategy
//! comparison and the pipelined-vs-sequential stepper comparison the CI
//! regression guards pin.
//!
//! Claims under measurement:
//! * forcing a single-die workload (SHD) onto 2 or 4 lockstep dies
//!   changes wall-clock (thread + bridge overhead vs per-die work
//!   shrinking) but **never** the readout — outputs are asserted
//!   bit-identical across die counts;
//! * a network that cannot compile on one die at all (> 1056 neuron
//!   cores) runs end-to-end at its natural die count;
//! * the `MinCut` cut-point optimizer ships strictly fewer remote
//!   packets per step across the host bridge than the PR 3
//!   `Contiguous` split on the same inputs (`--guard-mincut` turns the
//!   comparison into a hard failure; CI passes it on every run);
//! * the pipelined stepper (bounded run-ahead, `--depth`, default 2)
//!   produces bit-identical rows to the sequential reference and does
//!   not cost wall-clock beyond a small synchronization margin
//!   (`--guard-pipeline` turns the 4-die wide-FC comparison into a hard
//!   failure; on multi-core hosts the pipeline should win outright, and
//!   the guard's margin only absorbs condvar overhead on core-starved
//!   CI runners).
//!
//! `--json <path>` writes the whole run as machine-readable perf JSON
//! (`BENCH_multichip.json` in CI, uploaded as an artifact so the perf
//! trajectory is tracked across PRs).
//!
//! ```sh
//! cargo bench --bench bench_multichip_scaling               # full run
//! cargo bench --bench bench_multichip_scaling -- \
//!     --samples 1 --json BENCH_multichip.json \
//!     --guard-mincut --guard-pipeline                          # CI smoke
//! ```

use std::time::Instant;

use taibai::api::workloads::{Shd, Workload};
use taibai::api::{Backend, ExecOptions, Sample, Session, ShardStrategy, Taibai};
use taibai::bench::Table;
use taibai::compiler::Objective;
use taibai::model;
use taibai::util::cli::Args;
use taibai::util::json::Json;

/// One measured configuration, for both the table and the JSON report.
struct Row {
    deployment: String,
    strategy: String,
    dies: usize,
    cores: usize,
    ms_per_sample: f64,
    us_per_step: f64,
    cc_visits_per_step: f64,
    remote_packets_per_step: f64,
    spikes_per_sample: f64,
}

fn measure(
    label: &str,
    session: &mut Session,
    data: &[Sample],
) -> (Row, Vec<Vec<Vec<f32>>>) {
    let total_steps: usize = data.iter().map(|s| s.timesteps()).sum();
    let mut spikes = 0u64;
    let mut outs = Vec::new();
    let start = Instant::now();
    for s in data {
        let r = session.run(s).expect("running sample");
        spikes += r.spikes;
        outs.push(r.outputs);
    }
    let secs = start.elapsed().as_secs_f64();
    let tele = session.telemetry();
    let sched = &tele.sched;
    let visits = sched.integ_cc_visits + sched.fire_cc_visits + sched.delay_cc_visits;
    let a = &tele.activity;
    let row = Row {
        deployment: label.to_string(),
        strategy: String::new(),
        dies: session.info().chips,
        cores: session.info().used_cores,
        ms_per_sample: secs / data.len() as f64 * 1e3,
        us_per_step: secs / total_steps.max(1) as f64 * 1e6,
        cc_visits_per_step: visits as f64 / sched.steps.max(1) as f64,
        remote_packets_per_step: a.remote_packets as f64 / a.timesteps.max(1) as f64,
        spikes_per_sample: spikes as f64 / data.len() as f64,
    };
    (row, outs)
}

/// Run the dataset `reps` times on one session and keep the fastest
/// wall-clock, in ms/sample: best-of-N squeezes scheduler noise out of
/// the pipelined-vs-sequential comparison.
fn best_ms_per_sample(session: &mut Session, data: &[Sample], reps: usize) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let start = Instant::now();
        for s in data {
            session.run(s).expect("running sample");
        }
        let ms = start.elapsed().as_secs_f64() / data.len() as f64 * 1e3;
        best = best.min(ms);
    }
    best
}

fn row_json(r: &Row) -> Json {
    Json::obj()
        .set("deployment", r.deployment.as_str())
        .set("strategy", r.strategy.as_str())
        .set("dies", r.dies)
        .set("cores", r.cores)
        .set("ms_per_sample", r.ms_per_sample)
        .set("us_per_step", r.us_per_step)
        .set("cc_visits_per_step", r.cc_visits_per_step)
        .set("remote_packets_per_step", r.remote_packets_per_step)
        .set("spikes_per_sample", r.spikes_per_sample)
}

fn print_row(t: &mut Table, r: &Row) {
    t.row(&[
        r.deployment.clone(),
        format!("{}", r.dies),
        format!("{}", r.cores),
        format!("{:.3}", r.ms_per_sample),
        format!("{:.1}", r.us_per_step),
        format!("{:.1}", r.cc_visits_per_step),
        format!("{:.1}", r.remote_packets_per_step),
        format!("{:.1}", r.spikes_per_sample),
    ]);
}

fn shd_session(seed: u64, chips: usize, strategy: ShardStrategy, sa: usize, depth: usize) -> Session {
    Shd { dendrites: true }
        .taibai(seed)
        .exec(ExecOptions {
            backend: Backend::Sharded { chips },
            strategy,
            sa_iters: sa,
            pipeline_depth: depth,
            ..ExecOptions::default()
        })
        .build()
        .expect("compiling SHD sharded")
}

fn wide_session(seed: u64, chips: usize, strategy: ShardStrategy, sa: usize, depth: usize) -> Session {
    let net = model::wide_fc_net(8, 600, 2, 4);
    let weights = model::wide_fc_weights(&net, seed);
    Taibai::new(net)
        .weights(weights)
        .exec(ExecOptions {
            backend: Backend::Sharded { chips },
            objective: Objective::Balanced(1),
            strategy,
            merge: false,
            sa_iters: sa,
            pipeline_depth: depth,
            ..ExecOptions::default()
        })
        .build()
        .expect("compiling the wide-FC net")
}

fn main() {
    let args = Args::from_env();
    let samples = args.usize("samples", 5);
    let seed = args.u64("seed", 42);
    let guard = args.has("guard-mincut");
    let guard_pipeline = args.has("guard-pipeline");
    let depth = args.usize("depth", 2).max(1);
    let reps = args.usize("reps", 3);

    let w = Shd { dendrites: true };
    let all = w.dataset(samples.max(1), seed);
    let data = &all[..samples.min(all.len())];

    let mut t = Table::new(&[
        "deployment",
        "dies",
        "cores",
        "ms/sample",
        "us/step",
        "CC visits/step",
        "remote pkts/step",
        "spikes/sample",
    ]);
    let mut scaling_json = Vec::new();

    // ---- SHD forced onto 1 / 2 / 4 dies ------------------------------
    let mut reference: Option<Vec<Vec<Vec<f32>>>> = None;
    for &chips in &[1usize, 2, 4] {
        let mut session = shd_session(seed, chips, ShardStrategy::default(), 0, 0);
        let (mut row, outs) = measure("SHD", &mut session, data);
        row.strategy = ShardStrategy::default().to_string();
        match &reference {
            None => reference = Some(outs),
            Some(r) => assert_eq!(
                r, &outs,
                "{chips}-die readout diverged from the 1-die reference"
            ),
        }
        scaling_json.push(row_json(&row));
        print_row(&mut t, &row);
    }

    // ---- over-capacity net at its natural die count ------------------
    let steps = 8usize;
    let probe = vec![Sample::poisson(8, steps, 0.5, seed)];
    let mut session = wide_session(seed, 0, ShardStrategy::default(), 0, 0);
    let (mut row, _) = measure("Wide-FC 1204c", &mut session, &probe);
    row.strategy = ShardStrategy::default().to_string();
    assert!(row.spikes_per_sample > 0.0, "wide net never spiked");
    scaling_json.push(row_json(&row));
    print_row(&mut t, &row);
    t.print();

    // ---- cut strategy: Contiguous (PR 3 baseline) vs MinCut ----------
    // Same inputs through both cuts; remote packets/step is the SerDes
    // traffic the topology-aware cut exists to reduce. The all-on
    // wide-FC probe saturates every neuron, so its numbers are exactly
    // reproducible; SHD uses the dataset samples above.
    let wide_probe = vec![Sample::poisson(8, steps, 1.0, seed)];
    type SessionBuilder = Box<dyn Fn(ShardStrategy, usize) -> Session>;
    let configs: Vec<(&str, SessionBuilder, usize, &[Sample])> = vec![
        (
            "SHD",
            Box::new(move |s: ShardStrategy, sa: usize| shd_session(seed, 4, s, sa, 0)),
            4,
            data,
        ),
        (
            "Wide-FC 1204c",
            Box::new(move |s: ShardStrategy, sa: usize| wide_session(seed, 4, s, sa, 0)),
            4,
            &wide_probe,
        ),
    ];

    let mut t2 = Table::new(&[
        "cut guard",
        "dies",
        "strategy",
        "remote pkts/step",
        "cut est/step",
        "ms/sample",
    ]);
    let mut guard_json = Vec::new();
    let mut guard_failures: Vec<String> = Vec::new();
    for (name, build, dies, cfg_data) in &configs {
        let mut per_strategy = Vec::new();
        for strategy in [ShardStrategy::Contiguous, ShardStrategy::MinCut] {
            let mut session = build(strategy, 0);
            assert_eq!(session.info().chips, *dies);
            let (mut row, _) = measure(name, &mut session, cfg_data);
            row.strategy = strategy.to_string();
            t2.row(&[
                name.to_string(),
                format!("{dies}"),
                strategy.to_string(),
                format!("{:.1}", row.remote_packets_per_step),
                format!("{:.2}", session.info().cut_traffic),
                format!("{:.3}", row.ms_per_sample),
            ]);
            per_strategy.push((strategy, row, session.info().cut_traffic));
        }
        // MinCut + SerDes-aware SA row (reported, not guarded: the SA
        // refines on-die placement on top of the cut)
        {
            let mut session = build(ShardStrategy::MinCut, 1000);
            let (mut row, _) = measure(name, &mut session, cfg_data);
            row.strategy = "mincut+sa".to_string();
            t2.row(&[
                name.to_string(),
                format!("{dies}"),
                row.strategy.clone(),
                format!("{:.1}", row.remote_packets_per_step),
                format!("{:.2}", session.info().cut_traffic),
                format!("{:.3}", row.ms_per_sample),
            ]);
            per_strategy.push((ShardStrategy::MinCut, row, session.info().cut_traffic));
        }
        let contig = &per_strategy[0].1;
        let mincut = &per_strategy[1].1;
        let reduction = contig.remote_packets_per_step - mincut.remote_packets_per_step;
        guard_json.push(
            Json::obj()
                .set("workload", *name)
                .set("dies", *dies)
                .set("contiguous", row_json(contig))
                .set("mincut", row_json(mincut))
                .set("mincut_sa", row_json(&per_strategy[2].1))
                .set("remote_reduction_per_step", reduction),
        );
        if guard && mincut.remote_packets_per_step >= contig.remote_packets_per_step {
            guard_failures.push(format!(
                "{name} x{dies}: MinCut must ship strictly fewer remote packets/step \
                 than Contiguous ({} vs {})",
                mincut.remote_packets_per_step, contig.remote_packets_per_step,
            ));
        }
    }
    t2.print();

    // ---- pipelined vs sequential stepper per die count ---------------
    // Same compiled image class, two step engines: the sequential
    // reference and the bounded-run-ahead pipeline. Rows are asserted
    // bit-identical first, then best-of-N wall-clock is compared. The
    // 4-die wide-FC config is the guarded one: it is the only workload
    // here with enough per-die work for the pipeline to amortize its
    // synchronization, so it is where a pipelined regression would be
    // a real loss rather than condvar noise.
    let mut t3 = Table::new(&[
        "pipeline",
        "dies",
        "depth",
        "seq ms/sample",
        "piped ms/sample",
        "speedup",
    ]);
    let mut pipe_json = Vec::new();
    type DepthBuilder = Box<dyn Fn(usize) -> Session>;
    let pipe_configs: Vec<(&str, DepthBuilder, usize, &[Sample], bool)> = vec![
        (
            "SHD",
            Box::new(move |d: usize| shd_session(seed, 2, ShardStrategy::default(), 0, d)),
            2,
            data,
            false,
        ),
        (
            "SHD",
            Box::new(move |d: usize| shd_session(seed, 4, ShardStrategy::default(), 0, d)),
            4,
            data,
            false,
        ),
        (
            "Wide-FC 1204c",
            Box::new(move |d: usize| wide_session(seed, 4, ShardStrategy::default(), 0, d)),
            4,
            &wide_probe,
            true,
        ),
    ];
    // On a multi-core host the pipeline overlaps per-die work and should
    // simply be faster. The guard margin exists for core-starved CI
    // runners, where both engines serialize onto one CPU and the
    // pipeline can only pay (bounded) synchronization overhead.
    const PIPELINE_GUARD_MARGIN: f64 = 1.25;
    for (name, build, dies, cfg_data, guarded) in &pipe_configs {
        let mut seq = build(0);
        let mut piped = build(depth);
        for (si, s) in cfg_data.iter().enumerate() {
            assert_eq!(
                seq.run(s).expect("sequential run").outputs,
                piped.run(s).expect("pipelined run").outputs,
                "{name} x{dies} depth {depth}: sample {si} rows diverged"
            );
        }
        let seq_ms = best_ms_per_sample(&mut seq, cfg_data, reps);
        let piped_ms = best_ms_per_sample(&mut piped, cfg_data, reps);
        let speedup = seq_ms / piped_ms.max(1e-9);
        t3.row(&[
            name.to_string(),
            format!("{dies}"),
            format!("{depth}"),
            format!("{seq_ms:.3}"),
            format!("{piped_ms:.3}"),
            format!("{speedup:.2}x"),
        ]);
        pipe_json.push(
            Json::obj()
                .set("workload", *name)
                .set("dies", *dies)
                .set("depth", depth)
                .set("sequential_ms_per_sample", seq_ms)
                .set("pipelined_ms_per_sample", piped_ms)
                .set("speedup", speedup),
        );
        if guard_pipeline && *guarded && piped_ms > seq_ms * PIPELINE_GUARD_MARGIN {
            guard_failures.push(format!(
                "{name} x{dies}: pipelined stepper slower than sequential beyond \
                 the {PIPELINE_GUARD_MARGIN}x margin ({piped_ms:.3} ms vs {seq_ms:.3} ms \
                 per sample, best of {reps})",
            ));
        }
    }
    t3.print();

    if let Some(path) = args.get("json") {
        let doc = Json::obj()
            .set("bench", "multichip_scaling")
            .set("samples", data.len())
            .set("seed", seed)
            .set("pipeline_depth", depth)
            .set("scaling", Json::Arr(scaling_json))
            .set("cut_strategies", Json::Arr(guard_json))
            .set("pipeline", Json::Arr(pipe_json));
        std::fs::write(path, doc.render() + "\n").expect("writing perf JSON");
        println!("\nperf JSON written to {path}");
    }

    // guard failures abort only *after* the perf JSON is on disk, so a
    // regression still leaves the artifact to quantify it
    assert!(
        guard_failures.is_empty(),
        "regression guard failed:\n{}",
        guard_failures.join("\n")
    );

    println!(
        "\nReadout rows are asserted bit-identical across die counts and step \
         engines; the wide net only exists beyond one die's 1056 cores.{}{}",
        if guard {
            " MinCut < Contiguous remote-packet guard: PASSED."
        } else {
            ""
        },
        if guard_pipeline {
            " Pipelined-vs-sequential wall-clock guard: PASSED."
        } else {
            ""
        }
    );
}
