//! Fig 13e — compiler-controlled mapping trade-off: sweeping the
//! placement objective from minimize-cores to maximize-throughput on one
//! SNN. Paper: cores 182 → 749 (×4) while energy efficiency drops
//! 6190 → 3590 FPS/W (÷1.7). `--ablate` also compares zigzag-only vs
//! +greedy/SA placement. The per-point report runs through an analytic
//! `api::Session` parameterized with the placement-derived hop count.

use taibai::api::{Backend, ExecOptions, Sample, Taibai};
use taibai::bench::Table;
use taibai::chip::fast::FastParams;
use taibai::compiler::{partition, placement};
use taibai::model;
use taibai::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let net = model::blocks5_net(); // one mid-size SNN, like the paper
    let rates = vec![0.13; net.layers.len()];

    let mut t = Table::new(&["neurons/NC", "cores", "fps", "fps/W", "avg hops"]);
    let mut first: Option<(usize, f64)> = None;
    let mut last: Option<(usize, f64)> = None;

    for npn in [256usize, 192, 128, 96, 64] {
        let limits = partition::Limits { neurons_per_nc: npn, ..Default::default() };
        let part = partition::partition(&net, &limits);
        let traffic = placement::traffic_matrix(&net, &part, &rates, 0.13);
        let cores = part.num_cores();
        // placement quality feeds avg_hops into the analytic model
        let cap = taibai::noc::NUM_CCS * taibai::topology::NCS_PER_CC;
        let hops = if cores <= cap {
            let init = placement::initial(cores);
            let opt = placement::optimize(&traffic, init, 3000, 42);
            placement::avg_hops(&traffic, &opt)
        } else {
            4.0 // multi-chip: pessimistic constant
        };

        let mut p = FastParams::default();
        p.firing_rates = rates.clone();
        p.default_rate = 0.13;
        p.nc_neuron_capacity = npn;
        p.avg_hops = hops.max(0.5);
        let mut session = Taibai::new(net.clone())
            .exec(ExecOptions {
                backend: Backend::Analytic,
                fast: p,
                ..ExecOptions::default()
            })
            .build()
            .expect("analytic deploy");
        session
            .run(&Sample::poisson(0, net.timesteps, 0.0, 1))
            .expect("analytic run");
        let m = session.metrics();

        t.row(&[
            format!("{npn}"),
            format!("{}", m.used_cores),
            format!("{:.1}", m.fps),
            format!("{:.1}", m.fps_per_w),
            format!("{hops:.2}"),
        ]);
        if first.is_none() {
            first = Some((m.used_cores, m.fps_per_w));
        }
        last = Some((m.used_cores, m.fps_per_w));
    }
    t.print();

    let (c0, e0) = first.unwrap();
    let (c1, e1) = last.unwrap();
    println!(
        "\ncores x{:.1} (paper: x4.1, 182→749); efficiency /{:.2} (paper: /1.7, 6190→3590)",
        c1 as f64 / c0 as f64,
        e0 / e1
    );
    assert!(c1 > c0, "throughput objective must use more cores");

    if args.has("ablate") {
        // placement ablation: zigzag vs optimized on the 128-npn point
        let limits = partition::Limits { neurons_per_nc: 128, ..Default::default() };
        let part = partition::partition(&net, &limits);
        let traffic = placement::traffic_matrix(&net, &part, &rates, 0.13);
        let zig = placement::initial(part.num_cores());
        let h0 = placement::avg_hops(&traffic, &zig);
        let opt = placement::optimize(&traffic, zig, 5000, 7);
        let h1 = placement::avg_hops(&traffic, &opt);
        println!("[ablation] placement: zigzag {h0:.2} hops -> +SA {h1:.2} hops");
    }
}
