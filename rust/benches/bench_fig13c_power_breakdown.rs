//! Fig 13c — power breakdown of TaiBai under a representative workload
//! (paper: memory 70.3 % dominates).

use taibai::api::workloads::Shd;
use taibai::api::{Backend, Workload};
use taibai::bench::Table;
use taibai::energy::EnergyModel;

fn main() {
    // representative workload: the SHD app (mixed sparse + FC traffic)
    let workload = Shd { dendrites: true };
    let mut session = workload.session(Backend::Detailed, 42).expect("compile");
    for s in workload.dataset(6, 7).iter().take(6) {
        session.run(s).expect("run");
    }
    let em = EnergyModel::default();
    let e = em.energy(&session.activity());

    let mut t = Table::new(&["component", "share", "bar"]);
    for (name, frac) in e.shares() {
        let bar = "#".repeat((frac * 50.0).round() as usize);
        t.row(&[name.into(), format!("{:.1}%", frac * 100.0), bar]);
    }
    t.print();
    println!(
        "\nmemory share {:.1}% (paper Fig 13c: 70.3% — 'the memory module \
         (including the accessing memory process of the NCs and schedulers) \
         consumes the most power')",
        e.memory_share() * 100.0
    );
    assert!(e.memory_share() > 0.5, "memory must dominate");
}
