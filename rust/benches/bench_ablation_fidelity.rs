//! Fidelity ablation (DESIGN.md §fidelity modes): the fast analytic
//! model vs the detailed ISA-level engine on a small net — SOP counts
//! must agree closely; energy within a documented band.

use taibai::bench::Table;
use taibai::chip::fast::{simulate, FastParams};
use taibai::compiler::{self, Options};
use taibai::coordinator::Deployment;
use taibai::datasets::SpikeSample;
use taibai::energy::EnergyModel;
use taibai::model::{Layer, NetDef, NeuronModel};
use taibai::util::Rng;

fn main() {
    let em = EnergyModel::default();
    let mut rng = Rng::new(9);

    // small FC net, measurable input rate
    let t_steps = 40;
    let rate = 0.3;
    let mut net = NetDef::new("fidelity", t_steps);
    net.layers.push(Layer::Input { size: 32 });
    net.layers.push(Layer::Fc {
        input: 32,
        output: 64,
        neuron: NeuronModel::Lif { tau: 0.5, vth: 50.0 }, // silent hidden
    });
    let w1: Vec<f32> = (0..32 * 64).map(|_| rng.f32() * 0.1).collect();

    // detailed run
    let r = compiler::compile(&net, &vec![vec![], w1], &Options::default()).unwrap();
    let mut d = Deployment::new(r.compiled);
    let mut spikes = Vec::new();
    let mut input_events = 0u64;
    for _ in 0..t_steps {
        let mut at = Vec::new();
        for ch in 0..32u16 {
            if rng.chance(rate) {
                at.push(ch);
                input_events += 1;
            }
        }
        spikes.push(at);
    }
    d.run_spikes(&SpikeSample { spikes, labels: vec![0] }).unwrap();
    let da = d.chip.activity();
    let detailed_sops = da.nc.sops;
    let detailed_energy = em.energy(&da).dynamic_j();

    // fast-mode prediction with the *measured* input rate
    let measured_rate = input_events as f64 / (32 * t_steps) as f64;
    let mut p = FastParams::default();
    p.firing_rates = vec![measured_rate, 0.0];
    let f = simulate(&net, &p, &em);

    // compare dynamic energies (fast's energy_per_sample_j additionally
    // includes static leakage over the estimated wall time, which has no
    // detailed-mode counterpart on an idle-dominated micro-workload)
    let fast_dynamic = em.energy(&f.activity).dynamic_j();
    let mut t = Table::new(&["metric", "detailed", "fast", "error"]);
    let rows: [(&str, f64, f64); 2] = [
        ("SOPs/sample", detailed_sops as f64, f.sops_per_sample as f64),
        ("dynamic energy (nJ)", detailed_energy * 1e9, fast_dynamic * 1e9),
    ];
    for (name, dv, fv) in rows {
        let err = (fv - dv).abs() / dv.max(1e-12);
        t.row(&[
            name.into(),
            format!("{dv:.1}"),
            format!("{fv:.1}"),
            format!("{:.1}%", err * 100.0),
        ]);
    }
    t.print();

    let sop_err = (f.sops_per_sample as f64 - detailed_sops as f64).abs()
        / detailed_sops as f64;
    println!("\nSOP agreement: {:.2}% error (target < 5%)", sop_err * 100.0);
    assert!(sop_err < 0.05, "fast mode SOP count diverged: {sop_err}");
    // energy: FIRE-stage costs are estimated, not interpreted — allow a
    // wider band than the SOP count
    let e_err = (fast_dynamic - detailed_energy).abs() / detailed_energy;
    println!("energy agreement: {:.0}% error (documented band < 60%)", e_err * 100.0);
    assert!(e_err < 0.6, "fast-mode energy diverged: {e_err}");
}
