//! Fidelity ablation (DESIGN.md §fidelity modes): the fast analytic
//! backend vs the detailed ISA-level engine on a small net — SOP counts
//! must agree closely; energy within a documented band. Both engines run
//! behind the same `api::Session` surface, on the *same* input sample.

use taibai::api::{Backend, ExecOptions, Sample, Taibai};
use taibai::bench::Table;
use taibai::energy::EnergyModel;
use taibai::model::{Layer, NetDef, NeuronModel};
use taibai::util::Rng;

fn main() {
    let em = EnergyModel::default();
    let mut rng = Rng::new(9);

    // small FC net, measurable input rate
    let t_steps = 40;
    let rate = 0.3;
    let mut net = NetDef::new("fidelity", t_steps);
    net.layers.push(Layer::Input { size: 32 });
    net.layers.push(Layer::Fc {
        input: 32,
        output: 64,
        neuron: NeuronModel::Lif { tau: 0.5, vth: 50.0 }, // silent hidden
    });
    let w1: Vec<f32> = (0..32 * 64).map(|_| rng.f32() * 0.1).collect();

    // one sample drives both engines
    let sample = Sample::poisson(32, t_steps, rate, 11);
    let measured = sample.input_rate(32);

    // detailed run
    let mut detailed = Taibai::new(net.clone())
        .weights(vec![vec![], w1])
        .build()
        .expect("compile");
    detailed.run(&sample).expect("detailed run");
    let da = detailed.activity();
    let detailed_sops = da.nc.sops;
    let detailed_energy = em.energy(&da).dynamic_j();

    // analytic prediction at the measured input rate, silent hidden
    let mut fast = Taibai::new(net)
        .rates(vec![measured, 0.0])
        .exec(ExecOptions {
            backend: Backend::Analytic,
            ..ExecOptions::default()
        })
        .build()
        .expect("analytic deploy");
    fast.run(&sample).expect("analytic run");
    let fa = fast.activity();
    let fast_sops = fa.nc.sops;

    // compare dynamic energies (the analytic energy_per_sample_j
    // additionally includes static leakage over the estimated wall
    // time, which has no detailed-mode counterpart on an
    // idle-dominated micro-workload)
    let fast_dynamic = em.energy(&fa).dynamic_j();
    let mut t = Table::new(&["metric", "detailed", "fast", "error"]);
    let rows: [(&str, f64, f64); 2] = [
        ("SOPs/sample", detailed_sops as f64, fast_sops as f64),
        ("dynamic energy (nJ)", detailed_energy * 1e9, fast_dynamic * 1e9),
    ];
    for (name, dv, fv) in rows {
        let err = (fv - dv).abs() / dv.max(1e-12);
        t.row(&[
            name.into(),
            format!("{dv:.1}"),
            format!("{fv:.1}"),
            format!("{:.1}%", err * 100.0),
        ]);
    }
    t.print();

    let sop_err =
        (fast_sops as f64 - detailed_sops as f64).abs() / detailed_sops as f64;
    println!("\nSOP agreement: {:.2}% error (target < 5%)", sop_err * 100.0);
    assert!(sop_err < 0.05, "fast mode SOP count diverged: {sop_err}");
    // energy: FIRE-stage costs are estimated, not interpreted — allow a
    // wider band than the SOP count
    let e_err = (fast_dynamic - detailed_energy).abs() / detailed_energy;
    println!("energy agreement: {:.0}% error (documented band < 60%)", e_err * 100.0);
    assert!(e_err < 0.6, "fast-mode energy diverged: {e_err}");
}
