//! Serving-load bench: open-loop Poisson arrivals across N synthetic
//! tenants mixing the ECG / SHD / BCI workloads, submitted to the
//! sharded `api::serve::Gateway`.
//!
//! Three gateways run side by side (one per workload, sharing nothing);
//! each arrival picks a tenant uniformly, the tenant's workload is
//! `tenant % 3`, and the whole sample is submitted non-blocking
//! (`Gateway::submit`) — a full admission queue sheds the arrival, the
//! open-loop generator does not retry (that's the load-shedding
//! contract under test). Every admitted stream's decoded decision is
//! compared bit-exactly against a sequential single-pool reference
//! computed up front, so the sweep doubles as a concurrency-correctness
//! check: threading may change *which* streams are admitted, never what
//! an admitted stream decodes to.
//!
//! The sweep is `rates × workers` (defaults: 3 arrival rates × {1, 2,
//! 4} worker threads per gateway); each column reports admitted /
//! shed / completed counts, the rejection breakdown, admitted
//! throughput, and p50/p99/p999 push latency from the gateway
//! histogram. `--json <path>` writes the grid as machine-readable perf
//! JSON (`BENCH_serve.json` in CI).
//!
//! `--guard-serve` turns the run into a gate:
//!   * every column reconciles its admission accounting and decodes
//!     bit-identically to the sequential reference;
//!   * at the lowest rate, the max-worker p99 stays within one
//!     histogram bucket (2×) of the single-worker baseline — sharding
//!     must not regress the uncontended tail;
//!   * at the highest (saturating) rate, the max-worker configuration
//!     admits strictly more streams than the single-worker baseline —
//!     scale-out must buy admitted throughput.
//!
//! ```sh
//! cargo bench --bench bench_serve_load                  # full sweep
//! cargo bench --bench bench_serve_load -- --arrivals 30 --samples 3 \
//!     --json BENCH_serve.json --guard-serve             # CI smoke
//! ```

use std::time::{Duration, Instant};

use taibai::api::workloads::{Bci, Ecg, Shd, Workload};
use taibai::api::{
    Backend, Gateway, GatewayConfig, GatewayError, Rejected, Sample, Session,
    SessionPool, Ticket,
};
use taibai::bench::Table;
use taibai::util::cli::Args;
use taibai::util::json::Json;
use taibai::util::Rng;

/// One (rate × workers) column of the sweep.
struct Column {
    rate: f64,
    workers: usize,
    arrivals: u64,
    admitted: u64,
    shed: u64,
    completed: u64,
    faults: u64,
    mismatches: u64,
    queue_full: u64,
    deadline: u64,
    saturated: u64,
    throughput_sps: f64,
    p50_us: f64,
    p99_us: f64,
    p999_us: f64,
    reconciled: bool,
}

/// Sequential single-pool reference decisions, one per (workload,
/// sample) — the bit-identity baseline every threaded column must hit.
fn reference_decisions(
    template: &Session,
    data: &[Sample],
) -> Vec<Option<(usize, f64)>> {
    let mut pool =
        SessionPool::new(template.fork().expect("forking the reference"), 1)
            .expect("building the reference pool");
    data.iter()
        .map(|s| {
            let id = pool.open().expect("reference open");
            for t in 0..s.timesteps() {
                pool.push(id, s.events_at(t)).expect("reference push");
            }
            pool.release(id).expect("reference release").decision
        })
        .collect()
}

#[allow(clippy::too_many_arguments)]
fn run_column(
    templates: &[Session],
    data: &[Vec<Sample>],
    refs: &[Vec<Option<(usize, f64)>>],
    rate: f64,
    workers: usize,
    cfg_base: &GatewayConfig,
    tenants: u64,
    arrivals: u64,
    seed: u64,
) -> Column {
    let cfg = GatewayConfig {
        workers,
        ..cfg_base.clone()
    };
    let gws: Vec<Gateway> = templates
        .iter()
        .map(|t| Gateway::new(t, cfg.clone()).expect("building a gateway"))
        .collect();

    // Arrival pattern is deterministic per column; only wall-clock
    // pacing (and therefore shedding) varies run to run.
    let mut rng = Rng::new(seed ^ ((workers as u64) << 32) ^ rate.to_bits());
    let mut counters = vec![0usize; data.len()];
    let mut tickets: Vec<(usize, usize, Ticket)> = Vec::with_capacity(arrivals as usize);
    let mut shed = 0u64;
    let t0 = Instant::now();
    let mut next = t0;
    for _ in 0..arrivals {
        next += Duration::from_secs_f64(-(1.0 - rng.f64()).ln() / rate);
        if let Some(pause) = next.checked_duration_since(Instant::now()) {
            std::thread::sleep(pause);
        }
        let tenant = rng.below(tenants);
        let w = (tenant % data.len() as u64) as usize;
        let sidx = counters[w] % data[w].len();
        counters[w] += 1;
        match gws[w].submit(tenant, data[w][sidx].clone(), None) {
            Ok(t) => tickets.push((w, sidx, t)),
            Err(GatewayError::Rejected(Rejected::QueueFull)) => shed += 1,
            Err(e) => panic!("submit failed: {e}"),
        }
    }

    let mut completed = 0u64;
    let mut faults = 0u64;
    let mut mismatches = 0u64;
    let mut waited_rejects = 0u64;
    for (w, sidx, ticket) in tickets {
        match ticket.wait() {
            Ok(rep) => {
                completed += 1;
                if rep.decision != refs[w][sidx] {
                    mismatches += 1;
                }
            }
            Err(GatewayError::Rejected(_)) => waited_rejects += 1,
            Err(e) => {
                faults += 1;
                eprintln!("stream fault: {e}");
            }
        }
    }
    let elapsed = t0.elapsed();

    let mut admitted = 0u64;
    let mut queue_full = 0u64;
    let mut deadline = 0u64;
    let mut saturated = 0u64;
    let mut hist = taibai::api::LatencyHistogram::default();
    let mut reconciled = true;
    for gw in &gws {
        let t = gw.telemetry();
        admitted += t.stats.opened;
        queue_full += t.rejected.queue_full;
        deadline += t.rejected.deadline;
        saturated += t.rejected.saturated;
        hist.merge(&t.histogram);
        reconciled &= t.reconciled();
    }
    // the generator's local counts must agree with gateway telemetry
    reconciled &= queue_full == shed
        && deadline + saturated == waited_rejects
        && admitted == completed + faults;

    Column {
        rate,
        workers,
        arrivals,
        admitted,
        shed,
        completed,
        faults,
        mismatches,
        queue_full,
        deadline,
        saturated,
        throughput_sps: completed as f64 / elapsed.as_secs_f64(),
        p50_us: hist.p50_us(),
        p99_us: hist.p99_us(),
        p999_us: hist.p999_us(),
        reconciled,
    }
}

fn main() {
    let args = Args::from_env();
    let tenants = args.u64("tenants", 12).max(1);
    let arrivals = args.u64("arrivals", 90).max(1);
    let samples = args.usize("samples", 6).max(1);
    let pool = args.usize("pool", 2);
    let queue_depth = args.usize("queue-depth", 16);
    let deadline_ms = args.u64("deadline-ms", 0);
    let seed = args.u64("seed", 42);
    let parse_list = |key: &str, default: &str| -> Vec<f64> {
        args.get_or(key, default)
            .split(',')
            .map(|s| {
                s.trim()
                    .parse()
                    .unwrap_or_else(|_| panic!("--{key} expects numbers, got {s:?}"))
            })
            .collect()
    };
    let rates = parse_list("rates", "200,1000,4000");
    let worker_counts: Vec<usize> =
        parse_list("workers", "1,2,4").iter().map(|&w| w as usize).collect();

    let workloads: Vec<Box<dyn Workload>> = vec![
        Box::new(Ecg {
            heterogeneous: true,
        }),
        Box::new(Shd { dendrites: true }),
        Box::new(Bci::default()),
    ];
    println!(
        "serve-load sweep: {} tenants over {} workloads, {} arrivals per column, \
         rates {rates:?} /s x workers {worker_counts:?}",
        tenants,
        workloads.len(),
        arrivals,
    );
    let templates: Vec<Session> = workloads
        .iter()
        .enumerate()
        .map(|(i, w)| {
            w.session(Backend::Detailed, seed.wrapping_add(i as u64))
                .expect("compiling a workload")
        })
        .collect();
    let data: Vec<Vec<Sample>> = workloads
        .iter()
        .enumerate()
        .map(|(i, w)| w.dataset(samples, seed.wrapping_add(i as u64)))
        .collect();
    let refs: Vec<Vec<Option<(usize, f64)>>> = templates
        .iter()
        .zip(&data)
        .map(|(t, d)| reference_decisions(t, d))
        .collect();

    let cfg_base = GatewayConfig {
        workers: 1,
        slots_per_worker: pool,
        queue_depth,
        deadline: (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms)),
    };
    let mut t = Table::new(&[
        "rate/s",
        "workers",
        "admitted",
        "shed",
        "completed",
        "q-full",
        "deadline",
        "saturated",
        "streams/s",
        "p50 µs",
        "p99 µs",
        "p999 µs",
        "ok",
    ]);
    let mut columns: Vec<Column> = Vec::new();
    for &rate in &rates {
        for &workers in &worker_counts {
            let c = run_column(
                &templates, &data, &refs, rate, workers, &cfg_base, tenants,
                arrivals, seed,
            );
            t.row(&[
                format!("{rate:.0}"),
                format!("{workers}"),
                format!("{}", c.admitted),
                format!("{}", c.shed),
                format!("{}", c.completed),
                format!("{}", c.queue_full),
                format!("{}", c.deadline),
                format!("{}", c.saturated),
                format!("{:.0}", c.throughput_sps),
                format!("{:.1}", c.p50_us),
                format!("{:.1}", c.p99_us),
                format!("{:.1}", c.p999_us),
                format!(
                    "{}",
                    c.reconciled && c.mismatches == 0 && c.faults == 0
                ),
            ]);
            columns.push(c);
        }
    }
    t.print();

    if let Some(path) = args.get("json") {
        let cols: Vec<Json> = columns
            .iter()
            .map(|c| {
                Json::obj()
                    .set("rate_per_s", c.rate)
                    .set("workers", c.workers)
                    .set("arrivals", c.arrivals)
                    .set("admitted", c.admitted)
                    .set("shed", c.shed)
                    .set("completed", c.completed)
                    .set("faults", c.faults)
                    .set("mismatches", c.mismatches)
                    .set("rejected_queue_full", c.queue_full)
                    .set("rejected_deadline", c.deadline)
                    .set("rejected_saturated", c.saturated)
                    .set("throughput_sps", c.throughput_sps)
                    .set("p50_us", c.p50_us)
                    .set("p99_us", c.p99_us)
                    .set("p999_us", c.p999_us)
                    .set("reconciled", c.reconciled)
            })
            .collect();
        let doc = Json::obj()
            .set("bench", "serve_load")
            .set("tenants", tenants)
            .set("arrivals", arrivals)
            .set("samples", samples)
            .set("slots_per_worker", pool)
            .set("queue_depth", queue_depth)
            .set("deadline_ms", deadline_ms)
            .set("seed", seed)
            .set("columns", Json::Arr(cols));
        std::fs::write(path, doc.render() + "\n").expect("writing perf JSON");
        println!("\nperf JSON written to {path}");
    }

    if args.has("guard-serve") {
        for c in &columns {
            assert!(
                c.reconciled,
                "rate {} x {} workers: admission accounting does not reconcile",
                c.rate, c.workers
            );
            assert_eq!(
                c.mismatches, 0,
                "rate {} x {} workers: threaded decisions diverged from the \
                 sequential reference",
                c.rate, c.workers
            );
            assert_eq!(
                c.faults, 0,
                "rate {} x {} workers: streams faulted",
                c.rate, c.workers
            );
        }
        let lo = rates.iter().copied().fold(f64::INFINITY, f64::min);
        let hi = rates.iter().copied().fold(0.0f64, f64::max);
        let wmin = *worker_counts.iter().min().expect("workers list");
        let wmax = *worker_counts.iter().max().expect("workers list");
        let find = |rate: f64, workers: usize| {
            columns
                .iter()
                .find(|c| c.rate == rate && c.workers == workers)
                .expect("column present")
        };
        if wmax > wmin {
            // tail guard: one log2 histogram bucket (2x) of slack for
            // scheduler noise; sharding must not regress the idle tail
            let (single, multi) = (find(lo, wmin), find(lo, wmax));
            assert!(
                multi.p99_us <= single.p99_us * 2.0 * 1.01,
                "low-rate p99 regressed: {} workers {:.1} µs vs {} worker {:.1} µs",
                wmax, multi.p99_us, wmin, single.p99_us
            );
            // scale-out guard: at the saturating rate, more workers
            // must admit strictly more streams
            let (single, multi) = (find(hi, wmin), find(hi, wmax));
            assert!(
                multi.admitted > single.admitted,
                "scale-out bought nothing at {} /s: {} workers admitted {} vs \
                 {} worker admitted {}",
                hi, wmax, multi.admitted, wmin, single.admitted
            );
        }
        println!("guard-serve: all gates passed");
    }
}
