//! Fig 15 — the three applications on the detailed engine: (a) accuracy
//! incl. the homogeneous ablations, (b) power, (c) energy efficiency
//! (FPS/W) vs the GPU baseline. Paper: power ≈0.34 W avg (~200× below
//! GPU), efficiency 296–855× GPU. Everything runs through one
//! `api::Session` per workload.

use taibai::api::workloads::{Bci, Ecg, Shd};
use taibai::api::{evaluate, Backend, Workload, WorkloadReport};
use taibai::bench::Table;

fn main() {
    let seed = 42;
    let apps: Vec<(Box<dyn Workload>, usize)> = vec![
        (Box::new(Ecg { heterogeneous: true }), 2),
        (Box::new(Shd { dendrites: true }), 20),
        (Box::new(Bci::default()), 8),
    ];
    let reports: Vec<WorkloadReport> = apps
        .iter()
        .map(|(w, n)| {
            let mut session = w.session(Backend::Detailed, seed).expect("compile");
            evaluate(w.as_ref(), &mut session, *n, seed).expect("run")
        })
        .collect();

    let mut t = Table::new(&[
        "application", "accuracy", "cores", "TaiBai W", "GPU W",
        "power ratio", "TaiBai fps/W", "GPU fps/W", "eff ratio",
    ]);
    for r in &reports {
        let gpu_eff = r.gpu_fps / r.gpu.power_w;
        t.row(&[
            r.name.clone(),
            format!("{:.1}%", r.accuracy * 100.0),
            format!("{}", r.used_cores),
            format!("{:.3}", r.power_w),
            format!("{:.1}", r.gpu.power_w),
            format!("{:.0}x", r.gpu.power_w / r.power_w),
            format!("{:.1}", r.fps_per_w),
            format!("{:.3}", gpu_eff),
            format!("{:.0}x", r.fps_per_w / gpu_eff),
        ]);
        assert!(
            r.gpu.power_w / r.power_w > 20.0,
            "{}: power advantage collapsed",
            r.name
        );
        assert!(r.fps_per_w > gpu_eff, "{}: efficiency advantage lost", r.name);
    }
    t.print();

    let avg_p: f64 =
        reports.iter().map(|r| r.power_w).sum::<f64>() / reports.len() as f64;
    println!(
        "\naverage TaiBai power {avg_p:.3} W (paper Fig 15b: ≈0.34 W, \
         ~200x below GPU; efficiency 296–855x GPU)"
    );

    // ablations (Fig 15's TaiBai-homogeneous bars): heterogeneity on vs off
    println!("\n[ablation] heterogeneous vs homogeneous deployments compile to:");
    let pairs: [(&str, Box<dyn Workload>, Box<dyn Workload>); 2] = [
        (
            "ECG",
            Box::new(Ecg { heterogeneous: true }),
            Box::new(Ecg { heterogeneous: false }),
        ),
        (
            "SHD",
            Box::new(Shd { dendrites: true }),
            Box::new(Shd { dendrites: false }),
        ),
    ];
    for (name, het, hom) in pairs {
        let s_het = het.session(Backend::Detailed, seed).expect("compile");
        let s_hom = hom.session(Backend::Detailed, seed).expect("compile");
        println!(
            "  {name}: het {} cores / hom {} cores (same topology, different neuron programs)",
            s_het.info().used_cores,
            s_hom.info().used_cores
        );
    }
}
