//! Fig 15 — the three applications on the detailed engine: (a) accuracy
//! incl. the homogeneous ablations, (b) power, (c) energy efficiency
//! (FPS/W) vs the GPU baseline. Paper: power ≈0.34 W avg (~200× below
//! GPU), efficiency 296–855× GPU.

use taibai::apps;
use taibai::bench::Table;

fn main() {
    let seed = 42;
    let reports = [
        apps::run_ecg_demo(2, seed),
        apps::run_shd_demo(20, seed),
        apps::run_bci_demo(8, seed),
    ];

    let mut t = Table::new(&[
        "application", "accuracy", "cores", "TaiBai W", "GPU W",
        "power ratio", "TaiBai fps/W", "GPU fps/W", "eff ratio",
    ]);
    for r in &reports {
        let gpu_eff = r.gpu_fps / r.gpu.power_w;
        t.row(&[
            r.name.clone(),
            format!("{:.1}%", r.accuracy * 100.0),
            format!("{}", r.used_cores),
            format!("{:.3}", r.power_w),
            format!("{:.1}", r.gpu.power_w),
            format!("{:.0}x", r.gpu.power_w / r.power_w),
            format!("{:.1}", r.fps_per_w),
            format!("{:.3}", gpu_eff),
            format!("{:.0}x", r.fps_per_w / gpu_eff),
        ]);
        assert!(
            r.gpu.power_w / r.power_w > 20.0,
            "{}: power advantage collapsed",
            r.name
        );
        assert!(r.fps_per_w > gpu_eff, "{}: efficiency advantage lost", r.name);
    }
    t.print();

    let avg_p: f64 =
        reports.iter().map(|r| r.power_w).sum::<f64>() / reports.len() as f64;
    println!(
        "\naverage TaiBai power {avg_p:.3} W (paper Fig 15b: ≈0.34 W, \
         ~200x below GPU; efficiency 296–855x GPU)"
    );

    // ablations (Fig 15's TaiBai-homogeneous bars): heterogeneity on vs off
    println!("\n[ablation] heterogeneous vs homogeneous deployments compile to:");
    for (name, d_het, d_hom) in [
        ("ECG", apps::deploy_ecg(true, seed), apps::deploy_ecg(false, seed)),
        ("SHD", apps::deploy_shd(true, seed), apps::deploy_shd(false, seed)),
    ] {
        println!(
            "  {name}: het {} cores / hom {} cores (same topology, different neuron programs)",
            d_het.compiled.used_cores, d_hom.compiled.used_cores
        );
    }
}
