//! Table IV — energy per synaptic operation, measured on the detailed
//! engine running a dense Type-2 workload, printed in the paper's
//! cross-chip comparison context.

use taibai::bench::Table;
use taibai::compiler::{self, Options};
use taibai::coordinator::Deployment;
use taibai::datasets::SpikeSample;
use taibai::energy::EnergyModel;
use taibai::model::{Layer, NetDef, NeuronModel};

fn main() {
    // Dense two-layer FC net driven hard: every input channel spikes
    // every step — a SOP-soaked workload for stable pJ/SOP measurement.
    let mut net = NetDef::new("sop-soak", 20);
    net.layers.push(Layer::Input { size: 64 });
    net.layers.push(Layer::Fc {
        input: 64,
        output: 128,
        neuron: NeuronModel::Lif { tau: 0.5, vth: 4.0 },
    });
    net.layers.push(Layer::Fc {
        input: 128,
        output: 16,
        neuron: NeuronModel::Readout { tau: 0.9 },
    });
    let w1 = vec![0.05f32; 64 * 128];
    let w2 = vec![0.05f32; 128 * 16];
    let r = compiler::compile(&net, &vec![vec![], w1, w2], &Options::default()).unwrap();
    let mut d = Deployment::new(r.compiled).unwrap();

    let spikes = vec![(0..64u16).collect::<Vec<_>>(); 20];
    d.run_spikes(&SpikeSample { spikes, labels: vec![0] }).unwrap();

    let em = EnergyModel::default();
    let a = d.chip.activity();
    let measured = em.pj_per_sop(&a);

    let mut t = Table::new(&["processor", "tech", "precision", "programmability", "pJ/SOP"]);
    // literature rows from the paper's Table IV
    for (p, tech, prec, prog, e) in [
        ("TrueNorth", "28nm", "1-bit", "LIF only", "26"),
        ("Loihi", "14nm", "1-9 bit", "LIF+STDP", "23.6"),
        ("Tianjic", "28nm", "8-bit", "LIF only", "1.54"),
        ("PAICORE", "28nm", "1-bit", "LIF+STDP", "0.19"),
        ("SpiNNaker", "130nm", "32-bit", "fully programmable", "11000"),
        ("Loihi2", "7nm", "1-9 bit", "programmable", "7.8"),
        ("Darwin3", "22nm", "1-16 bit", "programmable", "5.47"),
        ("TaiBai (paper)", "28nm", "16-bit", "fully programmable", "2.61"),
    ] {
        t.row(&[p.into(), tech.into(), prec.into(), prog.into(), e.into()]);
    }
    t.row(&[
        "TaiBai (this model)".into(),
        "28nm-class".into(),
        "16-bit".into(),
        "fully programmable".into(),
        format!("{measured:.2}"),
    ]);
    t.print();
    println!(
        "\nmeasured on {} SOPs through the detailed ISA engine \
         (paper: 2.61 pJ; shape check: programmable 16-bit chips sit \
         between PAICORE's 1-bit 0.19 pJ and SpiNNaker's CPU-based nJ)",
        a.nc.sops
    );
    assert!((measured - 2.61).abs() < 1.3, "pJ/SOP drifted: {measured}");
}
