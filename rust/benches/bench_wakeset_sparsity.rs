//! Wake-set sparsity bench: pins the event-driven engine's win on the
//! SHD workload (700 input channels, the widest paper app).
//!
//! For input sparsity levels 0% (quiescent), 1%, 10%, and 50% it
//! reports CC visits per timestep (INTEG + FIRE + delay phases, from
//! [`taibai::chip::SchedStats`]) and wall-clock per sample. The claim
//! under test: visits scale with the columns actually touched by
//! traffic — a quiescent step visits **zero** columns — not with
//! deployment size, which is what a scan-every-column engine pays.
//!
//! A second section races the statically scheduled engine
//! ([`taibai::chip::StepSchedule::Static`]) against wake-set
//! bookkeeping on the same image and streams: per-step wall-clock and
//! CC visits for both, with min-over-`--repeats` timing to shed timer
//! noise. `--guard-schedule` turns the claim "scheduled is never
//! slower once traffic is dense (≥ 10% input rate)" into a hard exit
//! code for CI.
//!
//! `--json <path>` writes the wake-set measurements as machine-readable
//! perf JSON (`BENCH_wakeset.json` in CI); `--json-schedule <path>`
//! writes the scheduled-vs-wakeset comparison (`BENCH_schedule.json`).
//! Both are uploaded as artifacts so the perf trajectory is tracked
//! across PRs.
//!
//! ```sh
//! cargo bench --bench bench_wakeset_sparsity              # full run
//! cargo bench --bench bench_wakeset_sparsity -- \
//!     --samples 1 --timesteps 10 --json BENCH_wakeset.json \
//!     --json-schedule BENCH_schedule.json --guard-schedule    # CI smoke
//! ```

use std::time::Instant;

use taibai::api::workloads::shd_weights;
use taibai::bench::Table;
use taibai::chip::{SchedStats, StepSchedule};
use taibai::compiler::{self, Compiled, Options};
use taibai::coordinator::Deployment;
use taibai::datasets::SpikeSample;
use taibai::model;
use taibai::util::cli::Args;
use taibai::util::json::Json;
use taibai::util::Rng;

const CHANNELS: usize = 700;

fn bernoulli_sample(timesteps: usize, rate: f64, rng: &mut Rng) -> SpikeSample {
    let mut spikes = Vec::with_capacity(timesteps);
    for _ in 0..timesteps {
        let mut at = Vec::new();
        for ch in 0..CHANNELS {
            if rng.chance(rate) {
                at.push(ch as u16);
            }
        }
        spikes.push(at);
    }
    SpikeSample { spikes, labels: vec![0] }
}

fn main() {
    let args = Args::from_env();
    let samples = args.usize("samples", 5);
    let timesteps = args.usize("timesteps", 100);
    let seed = args.u64("seed", 42);

    let net = model::dhsnn_shd(true);
    let r = compiler::compile(
        &net,
        &shd_weights(true, seed),
        &Options {
            rates: vec![0.012, 0.025, 0.1],
            schedule: true,
            ..Default::default()
        },
    )
    .expect("compiling the SHD workload");
    assert!(r.compiled.schedule.is_some(), "SHD image carries no visit program");
    let configured_ccs = r.compiled.config.ccs.len();
    let compiled = r.compiled;
    println!(
        "SHD deployment: {} CCs / {} NCs configured, {timesteps} steps x {samples} samples per level\n",
        configured_ccs,
        compiled.used_cores
    );

    let mut t = Table::new(&[
        "input rate",
        "CC visits/step",
        "of configured",
        "ms/sample",
        "spikes/sample",
    ]);
    let mut levels = Vec::new();
    for &rate in &[0.0, 0.01, 0.10, 0.50] {
        let mut d = Deployment::new(compiled.clone()).expect("deploying");
        d.chip.schedule = StepSchedule::default();
        let mut rng = Rng::new(seed ^ (rate * 1000.0) as u64);
        let data: Vec<SpikeSample> = (0..samples)
            .map(|_| bernoulli_sample(timesteps, rate, &mut rng))
            .collect();
        let mut spikes_total = 0u64;
        let start = Instant::now();
        for s in &data {
            d.reset_state().expect("resetting between samples");
            spikes_total += d.run_spikes(s).expect("running sample").spikes;
        }
        let secs = start.elapsed().as_secs_f64();
        let sched = d.chip.sched;
        let visits =
            sched.integ_cc_visits + sched.fire_cc_visits + sched.delay_cc_visits;
        let per_step = visits as f64 / sched.steps.max(1) as f64;
        t.row(&[
            format!("{:>4.0}%", rate * 100.0),
            format!("{per_step:.2}"),
            format!("{:.0}%", per_step / configured_ccs as f64 * 100.0),
            format!("{:.3}", secs / samples as f64 * 1e3),
            format!("{:.1}", spikes_total as f64 / samples as f64),
        ]);
        levels.push(
            Json::obj()
                .set("input_rate", rate)
                .set("cc_visits_per_step", per_step)
                .set("configured_ccs", configured_ccs)
                .set("ms_per_sample", secs / samples as f64 * 1e3)
                .set("spikes_per_sample", spikes_total as f64 / samples as f64),
        );
        if rate == 0.0 {
            assert_eq!(
                visits, 0,
                "a quiescent deployment must visit zero columns"
            );
        }
    }
    t.print();

    if let Some(path) = args.get("json") {
        let doc = Json::obj()
            .set("bench", "wakeset_sparsity")
            .set("samples", samples)
            .set("timesteps", timesteps)
            .set("seed", seed)
            .set("configured_ccs", configured_ccs)
            .set("used_cores", compiled.used_cores)
            .set("levels", Json::Arr(levels));
        std::fs::write(path, doc.render() + "\n").expect("writing perf JSON");
        println!("\nperf JSON written to {path}");
    }

    println!(
        "\nCC visits track active columns (0 when quiescent), not the \
         {configured_ccs}-column deployment — the wake-set sparsity win."
    );

    // ---- scheduled vs wake-set on the same image and streams ----
    let repeats = args.usize("repeats", 3);
    println!("\nScheduled vs wake-set engine (min wall-clock over {repeats} repeats):\n");
    let mut t = Table::new(&[
        "input rate",
        "wake µs/step",
        "sched µs/step",
        "sched/wake",
        "static visits/step",
    ]);
    let mut levels = Vec::new();
    let mut guard_failures = Vec::new();
    for &rate in &[0.0, 0.01, 0.10, 0.50] {
        let mut rng = Rng::new(seed ^ (rate * 1000.0) as u64);
        let data: Vec<SpikeSample> = (0..samples)
            .map(|_| bernoulli_sample(timesteps, rate, &mut rng))
            .collect();
        let (wake_secs, wake_stats) = time_engine(&compiled, false, &data, repeats);
        let (sched_secs, sched_stats) = time_engine(&compiled, true, &data, repeats);
        let steps = sched_stats.steps.max(1) as f64;
        let wake_us = wake_secs / steps * 1e6;
        let sched_us = sched_secs / steps * 1e6;
        let static_per_step = sched_stats.static_cc_visits as f64 / steps;
        t.row(&[
            format!("{:>4.0}%", rate * 100.0),
            format!("{wake_us:.3}"),
            format!("{sched_us:.3}"),
            format!("{:.2}x", sched_us / wake_us.max(f64::MIN_POSITIVE)),
            format!("{static_per_step:.2}"),
        ]);
        levels.push(
            Json::obj()
                .set("input_rate", rate)
                .set("wake_us_per_step", wake_us)
                .set("sched_us_per_step", sched_us)
                .set(
                    "wake_cc_visits_per_step",
                    (wake_stats.integ_cc_visits
                        + wake_stats.fire_cc_visits
                        + wake_stats.delay_cc_visits) as f64
                        / steps,
                )
                .set(
                    "sched_cc_visits_per_step",
                    (sched_stats.integ_cc_visits
                        + sched_stats.fire_cc_visits
                        + sched_stats.delay_cc_visits) as f64
                        / steps,
                )
                .set("static_cc_visits_per_step", static_per_step),
        );
        assert_eq!(
            wake_stats.static_cc_visits, 0,
            "wake-set mode must never bump the static counter"
        );
        // SHD is fully feed-forward, so once traffic flows the program
        // must be serving visits.
        if rate > 0.0 {
            assert!(
                sched_stats.static_cc_visits > 0,
                "scheduled run at {rate} carried no static visits"
            );
        }
        if rate >= 0.10 && sched_us > wake_us {
            guard_failures.push(format!(
                "at {:.0}% input rate: scheduled {sched_us:.3} µs/step > \
                 wake-set {wake_us:.3} µs/step",
                rate * 100.0
            ));
        }
    }
    t.print();

    if let Some(path) = args.get("json-schedule") {
        let doc = Json::obj()
            .set("bench", "schedule_vs_wakeset")
            .set("samples", samples)
            .set("timesteps", timesteps)
            .set("repeats", repeats)
            .set("seed", seed)
            .set("configured_ccs", configured_ccs)
            .set("levels", Json::Arr(levels));
        std::fs::write(path, doc.render() + "\n").expect("writing schedule perf JSON");
        println!("\nschedule perf JSON written to {path}");
    }

    if args.has("guard-schedule") && !guard_failures.is_empty() {
        eprintln!("\n--guard-schedule FAILED:");
        for f in &guard_failures {
            eprintln!("  {f}");
        }
        std::process::exit(1);
    }
    println!(
        "\nAt dense traffic the static program drains whole CC ranges \
         without wake-set bookkeeping; at 0% both engines stay asleep."
    );
}

/// Min-over-repeats wall-clock for one engine over `data`, returning
/// the scheduler counters from the fastest repeat (counters are
/// deterministic across repeats — only the clock varies).
fn time_engine(
    compiled: &Compiled,
    scheduled: bool,
    data: &[SpikeSample],
    repeats: usize,
) -> (f64, SchedStats) {
    let mut best = f64::INFINITY;
    let mut stats = SchedStats::default();
    for _ in 0..repeats.max(1) {
        let mut d = Deployment::new(compiled.clone()).expect("deploying");
        if !scheduled {
            d.chip.schedule = StepSchedule::default();
        }
        let start = Instant::now();
        for s in data {
            d.reset_state().expect("resetting between samples");
            d.run_spikes(s).expect("running sample");
        }
        let secs = start.elapsed().as_secs_f64();
        if secs < best {
            best = secs;
            stats = d.chip.sched;
        }
    }
    (best, stats)
}
