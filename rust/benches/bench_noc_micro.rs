//! NoC microbenchmarks (perf-pass instrumentation): routing-mode costs,
//! tag-filtered multicast overhead, and the simulator's own hot-loop
//! throughput (events/s) — the §Perf "L3 should not be the bottleneck"
//! check.

use taibai::bench::{si, Table};
use taibai::isa::assembler::assemble;
use taibai::nc::{NcEvent, NeuronCore};
use taibai::noc::router::Mesh;
use taibai::noc::{cc_id, NUM_CCS};
use taibai::topology::RouteMode;

fn main() {
    // routing cost table
    let mut t = Table::new(&["mode", "deliveries", "traversals", "latency cyc"]);
    let mut mesh = Mesh::new();
    for (name, mode) in [
        ("unicast corner->corner", RouteMode::Unicast { x: 11, y: 10 }),
        ("multicast 4x4 region", RouteMode::Multicast { x0: 4, y0: 4, x1: 7, y1: 7 }),
        ("multicast 8x8 region", RouteMode::Multicast { x0: 2, y0: 2, x1: 9, y1: 9 }),
        ("broadcast", RouteMode::Broadcast),
    ] {
        let r = mesh.route(cc_id(0, 0), mode);
        t.row(&[
            name.into(),
            format!("{}", r.deliveries.len()),
            format!("{}", r.link_traversals),
            format!("{}", r.latency),
        ]);
    }
    t.print();

    // mesh model throughput
    let mut m = Mesh::new();
    let secs = taibai::bench::time(2, 10, || {
        for s in 0..NUM_CCS {
            m.route(s, RouteMode::Unicast { x: 5, y: 5 });
        }
    });
    println!("\nmesh route(): {} routes/s", si(NUM_CCS as f64 / secs));

    // NC interpreter throughput on the dense INTEG loop
    let integ = assemble(
        "loop:\nrecv\nld.f r6, r2, 256\nlocacc.f r6, r1, 128\nb loop",
    )
    .unwrap();
    let mut nc = NeuronCore::new(4096);
    nc.load_integ(&integ);
    let batch = 10_000;
    let secs = taibai::bench::time(1, 5, || {
        for i in 0..batch {
            nc.push_event(NcEvent {
                kind: taibai::isa::EventKind::Spike,
                neuron: (i % 64) as u16,
                axon: (i % 32) as u16,
                data: 0,
            });
        }
        nc.run(u64::MAX).unwrap();
    });
    println!(
        "NC interpreter: {} events/s, {} instr/s",
        si(batch as f64 / secs),
        si(batch as f64 * 4.0 / secs)
    );
}
