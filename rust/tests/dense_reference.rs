//! Golden dense-reference cross-checks: workload-shaped nets (ECG's
//! recurrent ALIF stack, SHD's dendritic DH-LIF stack, BCI's sparse
//! random-projection stack with the on-chip learning head) compared
//! bit-exactly against every compiled engine, plus the regression pin
//! for the sparse-destination fan-out aliasing bug and the 200-case
//! seeded fuzz sweep from the issue's acceptance criteria.
//!
//! All weights live on the generator's exactness grid (1/32 spike
//! weights with small fan-in; 1/8-grid dense inputs against ≤ 4/32
//! first-layer weights), so every comparison is exact `f32 ==`: any
//! mismatch is a routing/codegen bug, not FP noise.

use taibai::fuzz::{
    aliased_divergence, run_case, run_fuzz, GenCase, GenSpec, Outcome, Stream,
};
use taibai::model::{Layer, NetDef, NeuronModel, Skip};
use taibai::util::Rng;

/// 1/32-grid spike weight, mostly excitatory.
fn spike_w(rng: &mut Rng) -> f32 {
    let mag = rng.range(1, 17) as f32 / 32.0;
    if rng.chance(0.2) {
        -mag
    } else {
        mag
    }
}

/// Row-sparse Fc blob: `fan` nonzero grid weights per target column.
fn fc_blob(rng: &mut Rng, n_in: usize, n_out: usize, fan: usize) -> Vec<f32> {
    let mut w = vec![0.0f32; n_in * n_out];
    for t in 0..n_out {
        for u in rng.sample_indices(n_in, fan.min(n_in)) {
            w[u * n_out + t] = spike_w(rng);
        }
    }
    w
}

fn spike_stream(rng: &mut Rng, channels: usize, steps: usize, rate: f64) -> Stream {
    let mut sp = Vec::with_capacity(steps);
    for _ in 0..steps {
        let mut row = Vec::new();
        for c in 0..channels {
            if rng.chance(rate) {
                row.push(c as u16);
            }
        }
        sp.push(row);
    }
    Stream::Spikes(sp)
}

fn case(net: NetDef, weights: Vec<Vec<f32>>, stream: Stream) -> GenCase {
    GenCase {
        seed: 0,
        net,
        weights,
        stream,
        learning: false,
        errors: Vec::new(),
        rejected: 0,
    }
}

/// Run a hand-built case through the full oracle and demand a clean
/// sweep: zero divergences, and the named engines actually ran.
fn assert_all_engines_match(c: &GenCase, must_run: &[&str]) {
    let report = run_case(&GenSpec::default(), c);
    let bad: Vec<_> = report.divergences().collect();
    assert!(bad.is_empty(), "engine divergences: {bad:#?}");
    for name in must_run {
        let e = report
            .engines
            .iter()
            .find(|e| e.engine == *name)
            .unwrap_or_else(|| panic!("engine {name} missing from report"));
        assert!(
            matches!(e.outcome, Outcome::Match),
            "{name} did not run clean: {:?}",
            e.outcome
        );
    }
}

/// ECG-shaped: recurrent ALIF hidden layer into a readout head. Also
/// pins the recurrent forward-axon rebase end-to-end — before the
/// `axon_pad` fix, a recurrent layer's forward spikes indexed the
/// readout's weight rows shifted by the recurrent input width.
#[test]
fn ecg_shaped_recurrent_alif_matches_everywhere() {
    let mut rng = Rng::new(11);
    let (n_in, hidden, n_out, steps) = (4, 24, 6, 40);
    let mut net = NetDef::new("ecg-shaped", steps);
    net.layers.push(Layer::Input { size: n_in });
    net.layers.push(Layer::Recurrent {
        input: n_in,
        size: hidden,
        neuron: NeuronModel::Alif { tau: 0.9, vth: 1.0, beta: 0.3, rho: 0.97 },
    });
    net.layers.push(Layer::Fc {
        input: hidden,
        output: n_out,
        neuron: NeuronModel::Readout { tau: 0.9 },
    });
    let mut w1 = vec![0.0f32; (n_in + hidden) * hidden];
    for t in 0..hidden {
        for u in rng.sample_indices(n_in, 3) {
            w1[u * hidden + t] = spike_w(&mut rng).abs().max(0.25);
        }
        for j in rng.sample_indices(hidden, 2) {
            w1[(n_in + j) * hidden + t] = spike_w(&mut rng);
        }
    }
    let w2 = fc_blob(&mut rng, hidden, n_out, 4);
    let stream = spike_stream(&mut rng, n_in, steps, 0.5);

    let c = case(net, vec![vec![], w1, w2], stream);
    // the net must actually spike through to the head, or the test is
    // vacuous
    let mut dense =
        taibai::fuzz::DenseRef::new(&c.net, &c.weights, false).unwrap();
    let rows = dense.run(&c.stream);
    assert!(
        rows.iter().flatten().any(|&v| v != 0.0),
        "ECG-shaped net never reached the readout"
    );
    assert_all_engines_match(&c, &["wake", "scan-all", "sharded-2-mincut"]);
}

/// SHD-shaped (scaled): dendritic DH-LIF hidden layer — per-branch
/// current banks, the fixed heterogeneous branch-tau table — into a
/// readout head.
#[test]
fn shd_shaped_dendritic_matches_everywhere() {
    let mut rng = Rng::new(12);
    let (n_in, hidden, branches, n_out, steps) = (40, 16, 4, 5, 30);
    let mut net = NetDef::new("shd-shaped", steps);
    net.layers.push(Layer::Input { size: n_in });
    net.layers.push(Layer::Fc {
        input: n_in,
        output: hidden,
        neuron: NeuronModel::DhLif { branches, tau_soma: 0.9, vth: 1.0 },
    });
    net.layers.push(Layer::Fc {
        input: hidden,
        output: n_out,
        neuron: NeuronModel::Readout { tau: 0.9 },
    });
    let mut w1 = vec![0.0f32; branches * n_in * hidden];
    for t in 0..hidden {
        for u in rng.sample_indices(n_in, 5) {
            let b = rng.range(0, branches);
            w1[(b * n_in + u) * hidden + t] = spike_w(&mut rng).abs();
        }
    }
    let w2 = fc_blob(&mut rng, hidden, n_out, 4);
    let stream = spike_stream(&mut rng, n_in, steps, 0.25);

    let c = case(net, vec![vec![], w1, w2], stream);
    let mut dense =
        taibai::fuzz::DenseRef::new(&c.net, &c.weights, false).unwrap();
    let rows = dense.run(&c.stream);
    assert!(
        rows.iter().flatten().any(|&v| v != 0.0),
        "SHD-shaped net never reached the readout"
    );
    assert_all_engines_match(&c, &["wake", "scan-all", "sharded-4-mincut"]);
}

/// BCI-shaped with the learning head: dense 1/8-grid input into a
/// sparse projection, a sparse spike layer, and a trained Fc readout.
/// The learning run compares the post-update head weight matrix
/// bit-exactly across every engine (single-die and sharded).
#[test]
fn bci_shaped_learning_run_matches_everywhere() {
    let mut rng = Rng::new(13);
    let (n_in, h1, h2, n_out, steps) = (16, 24, 16, 4, 24);
    let mut net = NetDef::new("bci-shaped", steps);
    net.layers.push(Layer::Input { size: n_in });
    net.layers.push(Layer::Sparse {
        input: n_in,
        output: h1,
        density: 0.25,
        neuron: NeuronModel::Lif { tau: 0.5, vth: 0.5 },
    });
    net.layers.push(Layer::Sparse {
        input: h1,
        output: h2,
        density: 0.25,
        neuron: NeuronModel::Lif { tau: 0.5, vth: 0.5 },
    });
    net.layers.push(Layer::Fc {
        input: h2,
        output: n_out,
        neuron: NeuronModel::Readout { tau: 0.9 },
    });
    // layer 1 sees payload-scaled dense input: ≤ 4/32 weights keep
    // products on the exact 1/256 grid
    let mut w1 = vec![0.0f32; n_in * h1];
    for t in 0..h1 {
        for u in rng.sample_indices(n_in, 4) {
            w1[u * h1 + t] = rng.range(1, 5) as f32 / 32.0;
        }
    }
    let mut w2 = vec![0.0f32; h1 * h2];
    for t in 0..h2 {
        for u in rng.sample_indices(h1, 4) {
            w2[u * h2 + t] = spike_w(&mut rng).abs().max(0.25);
        }
    }
    let w3 = fc_blob(&mut rng, h2, n_out, 4);

    let mut vals = Vec::with_capacity(steps);
    for _ in 0..steps {
        let row: Vec<f32> = (0..n_in)
            .map(|_| {
                if rng.chance(0.5) {
                    rng.range(1, 9) as f32 / 8.0
                } else {
                    0.0
                }
            })
            .collect();
        vals.push(row);
    }

    let c = GenCase {
        seed: 0,
        net,
        weights: vec![vec![], w1, w2, w3],
        stream: Stream::Dense(vals),
        learning: true,
        errors: vec![0.5, -0.25, 0.125, -0.5],
        rejected: 0,
    };
    let mut dense =
        taibai::fuzz::DenseRef::new(&c.net, &c.weights, true).unwrap();
    let rows = dense.run(&c.stream);
    assert!(
        rows.iter().flatten().any(|&v| v != 0.0),
        "BCI-shaped net never reached the readout"
    );
    let before = dense.head_weights();
    dense.learn(&c.errors);
    assert_ne!(before, dense.head_weights(), "learning was a no-op");
    assert_all_engines_match(&c, &["wake", "scan-all", "sharded-2-mincut"]);
}

/// The bug this subsystem exists to kill: a spike-fed sparse
/// destination where upstream neuron 1 (not 0) fires. The pre-fix
/// encoding aliased every upstream spike onto the destination's first
/// DT slot, crediting upstream 0's weights instead — caught by the
/// dense reference; the fixed encoding matches it exactly.
#[test]
fn sparse_fanout_aliasing_diverges_pre_fix_and_matches_post_fix() {
    let steps = 8;
    let mut net = NetDef::new("aliasing-pin", steps);
    net.layers.push(Layer::Input { size: 2 });
    net.layers.push(Layer::Fc {
        input: 2,
        output: 2,
        neuron: NeuronModel::Lif { tau: 0.5, vth: 1.0 },
    });
    net.layers.push(Layer::Sparse {
        input: 2,
        output: 2,
        density: 0.5,
        neuron: NeuronModel::Lif { tau: 0.5, vth: 0.125 },
    });
    net.layers.push(Layer::Fc {
        input: 2,
        output: 2,
        neuron: NeuronModel::Readout { tau: 0.5 },
    });
    // channel i drives hidden i at exactly vth
    let w1 = vec![1.0, 0.0, 0.0, 1.0];
    // upstream 0 → dest 0 (0.5); upstream 1 → dest 1 (0.25): distinct
    // rows, so aliasing u=1 onto u=0's slot flips which neuron fires
    let w2 = vec![0.5, 0.0, 0.0, 0.25];
    // dest i → readout i
    let w3 = vec![0.5, 0.0, 0.0, 0.5];
    // only channel 1 is driven: the correct engine lights readout 1,
    // the aliased encoding lights readout 0
    let mut sp = vec![vec![1u16]; steps];
    sp[steps - 1] = vec![];
    let c = case(net, vec![vec![], w1, w2, w3], Stream::Spikes(sp));

    let spec = GenSpec::default();
    let d = aliased_divergence(&spec, &c)
        .expect("pre-fix encoding must diverge from the dense reference");
    assert_eq!(d.engine, "aliased");
    assert!(d.step.is_some(), "divergence must name a step: {d:#?}");

    // and the shipped (fixed) encoding sails through every engine
    assert_all_engines_match(&c, &["wake", "scan-all"]);
}

/// A delayed skip across the oracle: source and destination widths
/// match, spikes arrive `delay` steps late, and every engine that
/// accepts the net agrees with the dense reference — including sharded
/// engines, now that the bridge orders delay-line releases by their
/// tagged release step.
#[test]
fn skip_connection_case_matches_or_refuses() {
    let mut rng = Rng::new(14);
    let (n_in, w, n_out, steps) = (6, 10, 3, 24);
    let mut net = NetDef::new("skip-shaped", steps);
    net.layers.push(Layer::Input { size: n_in });
    for li in 0..3usize {
        let input = if li == 0 { n_in } else { w };
        net.layers.push(Layer::Fc {
            input,
            output: w,
            neuron: NeuronModel::Lif { tau: 0.75, vth: 0.5 },
        });
    }
    net.layers.push(Layer::Fc {
        input: w,
        output: n_out,
        neuron: NeuronModel::Readout { tau: 0.9 },
    });
    net.skips.push(Skip { from: 1, to: 3 });
    let mut weights = vec![Vec::new()];
    weights.push(fc_blob(&mut rng, n_in, w, 3));
    for _ in 0..2 {
        weights.push(fc_blob(&mut rng, w, w, 3));
    }
    weights.push(fc_blob(&mut rng, w, n_out, 3));
    let stream = spike_stream(&mut rng, n_in, steps, 0.5);

    let c = case(net, weights, stream);
    let report = run_case(&GenSpec::default(), &c);
    let bad: Vec<_> = report.divergences().collect();
    assert!(bad.is_empty(), "engine divergences: {bad:#?}");
    let matched = report
        .engines
        .iter()
        .filter(|e| matches!(e.outcome, Outcome::Match))
        .count();
    assert!(matched >= 2, "too few engines accepted the skip net");
}

/// The issue's acceptance sweep: 200 sequentially-seeded cases across
/// dense/sparse/recurrent/dendritic/skip/learning nets, every engine,
/// zero divergences.
#[test]
fn fuzz_200_seeded_cases_zero_divergences() {
    let report = run_fuzz(&GenSpec::default(), 200, 6);
    assert!(
        report.cases >= 190,
        "generator gave up too often: {} of 200",
        report.cases
    );
    assert!(report.learning_cases > 0, "no learning case in the sweep");
    assert!(
        report.ok(),
        "divergences: {:#?}\nfirst repro: {}",
        report.divergences,
        report.divergences[0].repro()
    );
}
