//! Analytic-vs-measured multi-chip reconciliation (the ROADMAP item:
//! "`chip::fast` models multi-chip analytically; reconcile its chips>1
//! estimates with measured `MultiChipDeployment` activity").
//!
//! The fast backend estimates cross-die traffic from the contiguous
//! layer-order layout and balanced CC-group→die split — the same
//! geometry `compiler::shard` produces under `ShardStrategy::Contiguous`
//! — so on a workload with *known* firing rates the estimate must land
//! within a pinned tolerance of the measured bridge counters.
//!
//! The wide FC net is driven with every input channel active on every
//! timestep: each neuron's in-band weight sum (≥ 4 × 0.5) is at least
//! twice the LIF threshold, so every hidden neuron fires every step and
//! the per-layer rates are 1.0 by construction, not by assumption.

use taibai::api::{Backend, ExecOptions, Sample, ShardStrategy, Taibai};
use taibai::chip::fast::FastParams;
use taibai::compiler::Objective;
use taibai::datasets::SpikeSample;
use taibai::model;

#[test]
fn fast_remote_traffic_matches_measured_bridge_counters() {
    let net = model::wide_fc_net(8, 600, 2, 4);
    let weights = model::wide_fc_weights(&net, 3);
    const T: usize = 12;
    let all_on = Sample::Spikes(SpikeSample {
        spikes: vec![(0..8u16).collect(); T],
        labels: vec![0],
    });

    // ---- measured: detailed lockstep dies, contiguous split ----------
    let mut measured = Taibai::new(net.clone())
        .weights(weights)
        .exec(ExecOptions {
            backend: Backend::Sharded { chips: 0 },
            objective: Objective::Balanced(1),
            strategy: ShardStrategy::Contiguous,
            merge: false,
            sa_iters: 0,
            ..ExecOptions::default()
        })
        .build()
        .expect("sharded compile");
    assert_eq!(measured.info().chips, 2, "wide FC needs exactly 2 dies");
    measured.run(&all_on).expect("sharded run");
    let am = measured.activity();
    assert!(am.remote_packets > 0, "dies never talked");
    assert_eq!(am.timesteps, T as u64);

    // per-edge counters are consistent with the aggregate
    let bridge = measured.telemetry().bridge.expect("bridge counters");
    let total: u64 = bridge.iter().flatten().sum();
    assert_eq!(total, am.remote_packets, "bridge matrix vs aggregate");
    for (i, row) in bridge.iter().enumerate() {
        assert_eq!(row[i], 0, "die {i} bridged to itself");
    }
    // feed-forward all-on drive: die 0 (early layers) must dominate
    assert!(bridge[0][1] > bridge[1][0], "traffic direction inverted");

    // ---- estimated: fast backend at the same geometry and rates ------
    let mut p = FastParams::default();
    p.nc_neuron_capacity = 1; // Balanced(1): one neuron per core
    p.firing_rates = vec![1.0, 1.0, 1.0, 0.0]; // saturated by construction
    let mut fast = Taibai::new(net)
        .exec(ExecOptions {
            backend: Backend::Analytic,
            fast: p,
            ..ExecOptions::default()
        })
        .build()
        .expect("analytic build");
    assert_eq!(fast.info().chips, 2, "analytic die count diverged");
    fast.run(&all_on).expect("analytic run");
    let af = fast.activity();
    assert!(af.remote_packets > 0, "analytic model predicts no bridge traffic");
    assert_eq!(af.timesteps, T as u64);

    // ---- pinned tolerance --------------------------------------------
    // Both sides ran T lockstep steps; the only honest slack is the
    // pipeline fill (layer 2 starts one step late) and CC-boundary
    // rounding, both ≪ 25%.
    let ratio = am.remote_packets as f64 / af.remote_packets as f64;
    assert!(
        ratio > 0.75 && ratio < 1.33,
        "measured {} vs estimated {} remote packets (ratio {ratio:.4}) \
         outside the pinned [0.75, 1.33] tolerance",
        am.remote_packets,
        af.remote_packets
    );
}
