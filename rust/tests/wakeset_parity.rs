//! Engine parity suite: the event-driven wake-set engine, the naive
//! scan-every-column reference (`Chip::scan_all`), and the statically
//! scheduled engine (compile-time [`taibai::chip::VisitProgram`]) must
//! all produce bit-identical results. Divergence means one engine lost
//! or invented work.
//!
//! Covered per workload (ECG / SHD / BCI): readout rows, spike counts,
//! routed-packet counts, the full [`ChipActivity`] counter set (so the
//! energy model prices every engine identically), and the scheduler's
//! own visit counters — including the pin that `static_cc_visits` is
//! zero in wake-set and scan-all modes and strictly positive whenever
//! a program with a non-empty static region carries traffic. Plus: a
//! quiescent compiled
//! deployment must cost zero column visits per step in every mode.

use taibai::api::workloads::{Bci, Ecg, Shd, Workload};
use taibai::api::Sample;
use taibai::chip::StepSchedule;
use taibai::compiler::{self, Options};
use taibai::coordinator::Deployment;

/// Three deployments of one compiled image: wake-set, scan-all, and
/// statically scheduled. All share the exact same image (compiled once,
/// with the visit program attached); the wake deployment resets its
/// schedule back to the default strategy, and the scan deployment keeps
/// the program installed so the test also exercises the `scan_all`
/// override.
fn build_trio(w: &dyn Workload, seed: u64) -> (Deployment, Deployment, Deployment) {
    let r = compiler::compile(
        &w.net(),
        &w.weights(seed),
        &Options {
            learning: w.learning(),
            rates: w.rates(),
            schedule: true,
            ..Default::default()
        },
    )
    .unwrap_or_else(|e| panic!("{}: compile failed: {e}", w.name()));
    assert!(
        r.compiled.schedule.is_some(),
        "{}: Options::schedule did not attach a visit program",
        w.name()
    );
    let mut wake = Deployment::new(r.compiled.clone()).unwrap();
    wake.chip.schedule = StepSchedule::default();
    let mut scan = Deployment::new(r.compiled.clone()).unwrap();
    scan.chip.scan_all = true;
    let sched = Deployment::new(r.compiled).unwrap();
    assert!(
        matches!(sched.chip.schedule, StepSchedule::Static(_)),
        "{}: deployment did not install the compiled visit program",
        w.name()
    );
    (wake, scan, sched)
}

fn run_one(d: &mut Deployment, s: &Sample) -> taibai::coordinator::SampleRun {
    d.reset_state().unwrap();
    match s {
        Sample::Spikes(sp) => d.run_spikes(sp).unwrap(),
        Sample::Dense(v) => d.run_values(v).unwrap(),
    }
}

fn run_both(
    wake: &mut Deployment,
    scan: &mut Deployment,
    s: &Sample,
) -> (taibai::coordinator::SampleRun, taibai::coordinator::SampleRun) {
    (run_one(wake, s), run_one(scan, s))
}

fn assert_parity(w: &dyn Workload, samples: usize, seed: u64) {
    let (mut wake, mut scan, mut sched) = build_trio(w, seed);
    for (k, s) in w.dataset(samples, seed).iter().take(samples).enumerate() {
        let a = run_one(&mut wake, s);
        let b = run_one(&mut scan, s);
        let c = run_one(&mut sched, s);
        assert_eq!(a.outputs, b.outputs, "{} sample {k}: readout rows diverged", w.name());
        assert_eq!(a.spikes, b.spikes, "{} sample {k}: spike counts diverged", w.name());
        assert_eq!(a.packets, b.packets, "{} sample {k}: packet counts diverged", w.name());
        assert_eq!(a.outputs, c.outputs, "{} sample {k}: scheduled readout diverged", w.name());
        assert_eq!(a.spikes, c.spikes, "{} sample {k}: scheduled spikes diverged", w.name());
        assert_eq!(a.packets, c.packets, "{} sample {k}: scheduled packets diverged", w.name());
    }
    assert_eq!(
        wake.chip.activity(),
        scan.chip.activity(),
        "{}: ChipActivity counters diverged (energy model would disagree)",
        w.name()
    );
    assert_eq!(
        wake.chip.activity(),
        sched.chip.activity(),
        "{}: scheduled ChipActivity diverged (energy model would disagree)",
        w.name()
    );
    assert_eq!(
        wake.chip.sched,
        scan.chip.sched,
        "{}: wake sets visited different columns than the predicate scan",
        w.name()
    );
    // Every engine does the same amount of column work; the scheduled
    // engine merely attributes part of it to the static program.
    let (a, b) = (&wake.chip.sched, &sched.chip.sched);
    assert_eq!(a.steps, b.steps, "{}: step counts diverged", w.name());
    assert_eq!(a.integ_cc_visits, b.integ_cc_visits, "{}: INTEG visits diverged", w.name());
    assert_eq!(a.fire_cc_visits, b.fire_cc_visits, "{}: FIRE visits diverged", w.name());
    assert_eq!(a.delay_cc_visits, b.delay_cc_visits, "{}: delay visits diverged", w.name());
    assert_eq!(a.static_cc_visits, 0, "{}: wake-set mode bumped the static counter", w.name());
    assert_eq!(
        scan.chip.sched.static_cc_visits,
        0,
        "{}: scan-all mode bumped the static counter",
        w.name()
    );
    // Positivity is pinned only when the program actually has a static
    // region: placement is free to co-locate a small net's static
    // layers with its dynamic ones on a single CC, which legitimately
    // leaves the whole image on the wake path.
    let prog = match &sched.chip.schedule {
        StepSchedule::Static(p) => p.clone(),
        StepSchedule::WakeSet => unreachable!("build_trio pinned a static program"),
    };
    if prog.static_ccs.is_empty() {
        assert_eq!(
            b.static_cc_visits, 0,
            "{}: fully dynamic program attributed static visits",
            w.name()
        );
    } else {
        assert!(
            b.static_cc_visits > 0,
            "{}: static program carried no traffic — nothing was scheduled",
            w.name()
        );
    }
    assert!(
        b.static_cc_visits <= b.integ_cc_visits + b.fire_cc_visits,
        "{}: static counter exceeds total INTEG+FIRE work",
        w.name()
    );
}

#[test]
fn ecg_wake_set_matches_scan_all_reference() {
    assert_parity(&Ecg { heterogeneous: true }, 2, 7);
}

#[test]
fn shd_wake_set_matches_scan_all_reference() {
    assert_parity(&Shd { dendrites: true }, 2, 3);
}

#[test]
fn bci_wake_set_matches_scan_all_reference() {
    assert_parity(&Bci { subpaths: 8, day: 2 }, 2, 11);
}

#[test]
fn bci_learning_step_matches_scan_all_reference() {
    let w = Bci { subpaths: 8, day: 2 };
    let (mut wake, mut scan, mut sched) = build_trio(&w, 5);
    let data = w.dataset(1, 5);
    let (a, b) = run_both(&mut wake, &mut scan, &data[0]);
    let c = run_one(&mut sched, &data[0]);
    assert_eq!(a.outputs, b.outputs);
    assert_eq!(a.outputs, c.outputs);
    // identical error injection must move identical weights — the
    // learning head sits in the dynamic region of the visit program,
    // so the scheduled engine routes its traffic over the wake path
    let errors = [0.5, -0.25, -0.15, -0.1];
    wake.learn_step(&errors).unwrap();
    scan.learn_step(&errors).unwrap();
    sched.learn_step(&errors).unwrap();
    assert_eq!(wake.chip.activity(), scan.chip.activity());
    assert_eq!(wake.chip.activity(), sched.chip.activity());
    let (a, b) = run_both(&mut wake, &mut scan, &data[0]);
    let c = run_one(&mut sched, &data[0]);
    assert_eq!(a.outputs, b.outputs, "post-learning runs diverged");
    assert_eq!(a.outputs, c.outputs, "scheduled post-learning run diverged");
}

#[test]
fn quiescent_deployment_visits_zero_columns() {
    let w = Ecg { heterogeneous: true };
    let (wake, _, sched) = build_trio(&w, 9);
    for (mode, mut d) in [("wake-set", wake), ("scheduled", sched)] {
        for _ in 0..10 {
            let r = d.chip.step(&[]).unwrap();
            assert_eq!(r.spikes, 0);
            assert!(r.outputs.is_empty());
        }
        assert_eq!(d.chip.sched.steps, 10);
        let visits = d.chip.sched.integ_cc_visits
            + d.chip.sched.fire_cc_visits
            + d.chip.sched.delay_cc_visits
            + d.chip.sched.static_cc_visits;
        assert_eq!(visits, 0, "{mode}: a silent deployment must not visit a column");
        assert_eq!(d.chip.activity().nc.instret, 0, "{mode}: no NC may execute");
    }
}
