//! Wake-set parity suite: the event-driven engine (bitset wake sets,
//! incremental bookkeeping) must produce results bit-identical to the
//! naive scan-every-column reference (`Chip::scan_all`), which derives
//! the same per-phase work sets by predicate scan each step. Divergence
//! means the incremental bookkeeping lost or invented work.
//!
//! Covered per workload (ECG / SHD / BCI): readout rows, spike counts,
//! routed-packet counts, the full [`ChipActivity`] counter set (so the
//! energy model prices both engines identically), and the scheduler's
//! own visit counters. Plus: a quiescent compiled deployment must cost
//! zero column visits per step.

use taibai::api::workloads::{Bci, Ecg, Shd, Workload};
use taibai::api::Sample;
use taibai::compiler::{self, Options};
use taibai::coordinator::Deployment;

/// Two deployments of the same compiled image: wake-set and scan-all.
fn build_pair(w: &dyn Workload, seed: u64) -> (Deployment, Deployment) {
    let r = compiler::compile(
        &w.net(),
        &w.weights(seed),
        &Options {
            learning: w.learning(),
            rates: w.rates(),
            ..Default::default()
        },
    )
    .unwrap_or_else(|e| panic!("{}: compile failed: {e}", w.name()));
    let wake = Deployment::new(r.compiled.clone()).unwrap();
    let mut scan = Deployment::new(r.compiled).unwrap();
    scan.chip.scan_all = true;
    (wake, scan)
}

fn run_both(
    wake: &mut Deployment,
    scan: &mut Deployment,
    s: &Sample,
) -> (taibai::coordinator::SampleRun, taibai::coordinator::SampleRun) {
    wake.reset_state().unwrap();
    scan.reset_state().unwrap();
    match s {
        Sample::Spikes(sp) => (wake.run_spikes(sp).unwrap(), scan.run_spikes(sp).unwrap()),
        Sample::Dense(d) => (wake.run_values(d).unwrap(), scan.run_values(d).unwrap()),
    }
}

fn assert_parity(w: &dyn Workload, samples: usize, seed: u64) {
    let (mut wake, mut scan) = build_pair(w, seed);
    for (k, s) in w.dataset(samples, seed).iter().take(samples).enumerate() {
        let (a, b) = run_both(&mut wake, &mut scan, s);
        assert_eq!(a.outputs, b.outputs, "{} sample {k}: readout rows diverged", w.name());
        assert_eq!(a.spikes, b.spikes, "{} sample {k}: spike counts diverged", w.name());
        assert_eq!(a.packets, b.packets, "{} sample {k}: packet counts diverged", w.name());
    }
    assert_eq!(
        wake.chip.activity(),
        scan.chip.activity(),
        "{}: ChipActivity counters diverged (energy model would disagree)",
        w.name()
    );
    assert_eq!(
        wake.chip.sched,
        scan.chip.sched,
        "{}: wake sets visited different columns than the predicate scan",
        w.name()
    );
}

#[test]
fn ecg_wake_set_matches_scan_all_reference() {
    assert_parity(&Ecg { heterogeneous: true }, 2, 7);
}

#[test]
fn shd_wake_set_matches_scan_all_reference() {
    assert_parity(&Shd { dendrites: true }, 2, 3);
}

#[test]
fn bci_wake_set_matches_scan_all_reference() {
    assert_parity(&Bci { subpaths: 8, day: 2 }, 2, 11);
}

#[test]
fn bci_learning_step_matches_scan_all_reference() {
    let w = Bci { subpaths: 8, day: 2 };
    let (mut wake, mut scan) = build_pair(&w, 5);
    let data = w.dataset(1, 5);
    let (a, b) = run_both(&mut wake, &mut scan, &data[0]);
    assert_eq!(a.outputs, b.outputs);
    // identical error injection must move identical weights
    let errors = [0.5, -0.25, -0.15, -0.1];
    wake.learn_step(&errors).unwrap();
    scan.learn_step(&errors).unwrap();
    assert_eq!(wake.chip.activity(), scan.chip.activity());
    let (a, b) = run_both(&mut wake, &mut scan, &data[0]);
    assert_eq!(a.outputs, b.outputs, "post-learning runs diverged");
}

#[test]
fn quiescent_deployment_visits_zero_columns() {
    let w = Ecg { heterogeneous: true };
    let (mut d, _) = build_pair(&w, 9);
    for _ in 0..10 {
        let r = d.chip.step(&[]).unwrap();
        assert_eq!(r.spikes, 0);
        assert!(r.outputs.is_empty());
    }
    assert_eq!(d.chip.sched.steps, 10);
    let visits = d.chip.sched.integ_cc_visits
        + d.chip.sched.fire_cc_visits
        + d.chip.sched.delay_cc_visits;
    assert_eq!(visits, 0, "a silent deployment must not visit a single column");
    assert_eq!(d.chip.activity().nc.instret, 0, "no NC may execute");
}
