//! Static verifier acceptance: every image the current compiler emits
//! must pass, hand-corrupted images must fail with the right diagnostic,
//! and the bug-compat aliased encoding must be rejected with chip
//! coordinates.

use taibai::api::workloads::{Bci, Ecg, Shd};
use taibai::api::Workload;
use taibai::compiler::verify::{verify, verify_sharded, VerifyError};
use taibai::compiler::{self, Compiled, Options, ShardStrategy};
use taibai::fuzz::{generate, GenSpec};
use taibai::model::gen::validate_options;
use taibai::model::{Layer, NetDef, NeuronModel};
use taibai::topology::RouteMode;

fn workload_opts(w: &dyn Workload) -> Options {
    Options {
        learning: w.learning(),
        rates: w.rates(),
        verify: false, // explicit verify calls below; avoids double work
        ..Default::default()
    }
}

fn compile_one(w: &dyn Workload, seed: u64) -> (NetDef, Vec<Vec<f32>>, Options, Compiled) {
    let net = w.net();
    let weights = w.weights(seed);
    let opts = workload_opts(w);
    let rep = compiler::compile(&net, &weights, &opts)
        .unwrap_or_else(|e| panic!("{} compile failed: {e}", w.name()));
    (net, weights, opts, rep.compiled)
}

/// Every packaged workload, on every engine configuration the repo
/// ships (single-die plus 2/4/8-die with both cut strategies), produces
/// an image the verifier accepts with zero errors.
#[test]
fn packaged_workloads_verify_clean_on_every_engine() {
    let workloads: Vec<Box<dyn Workload>> = vec![
        Box::new(Ecg { heterogeneous: true }),
        Box::new(Shd { dendrites: true }),
        Box::new(Bci::default()),
    ];
    for w in &workloads {
        let (net, weights, opts, compiled) = compile_one(w.as_ref(), 42);
        let r = verify(&compiled, &net, opts.learning);
        assert!(r.ok(), "{} single-die: {}\n{r}", w.name(), r.summary());
        for chips in [2usize, 4, 8] {
            for strategy in [ShardStrategy::Contiguous, ShardStrategy::MinCut] {
                let mut o = opts.clone();
                o.strategy = strategy;
                let rep = compiler::compile_sharded(&net, &weights, &o, chips)
                    .unwrap_or_else(|e| {
                        panic!("{} sharded-{chips}-{strategy}: {e}", w.name())
                    });
                let r = verify_sharded(&rep.sharded, &net, o.learning);
                assert!(
                    r.ok(),
                    "{} sharded-{chips}-{strategy}: {}\n{r}",
                    w.name(),
                    r.summary()
                );
            }
        }
    }
}

/// 200-seed generated-net sweep: no false positives on anything the
/// compiler actually emits, across single-die and 2/4/8-die builds.
#[test]
fn corpus_sweep_has_no_false_positives() {
    let spec = GenSpec::default();
    let mut checked = 0usize;
    for i in 0..200u64 {
        let seed = 3_000 + i;
        let Ok(case) = generate(&spec, seed) else { continue };
        let mut opts = validate_options(case.learning, &spec);
        opts.verify = false;
        let Ok(rep) = compiler::compile(&case.net, &case.weights, &opts) else {
            continue; // typed refusal (capacity etc.) is not a verifier bug
        };
        let r = verify(&rep.compiled, &case.net, case.learning);
        assert!(r.ok(), "seed {seed} single-die: {}\n{r}", r.summary());
        checked += 1;
        for chips in [2usize, 4, 8] {
            let strategies: &[ShardStrategy] = if chips == 2 {
                &[ShardStrategy::Contiguous, ShardStrategy::MinCut]
            } else {
                &[ShardStrategy::MinCut]
            };
            for &strategy in strategies {
                let mut o = opts.clone();
                o.strategy = strategy;
                let Ok(rep) = compiler::compile_sharded(&case.net, &case.weights, &o, chips)
                else {
                    continue;
                };
                let r = verify_sharded(&rep.sharded, &case.net, case.learning);
                assert!(
                    r.ok(),
                    "seed {seed} sharded-{chips}-{strategy}: {}\n{r}",
                    r.summary()
                );
                checked += 1;
            }
        }
    }
    assert!(checked > 200, "corpus mostly refused ({checked} images checked)");
}

/// The pre-fix aliased sparse fan-out encoding must be *rejected*, with
/// a diagnostic that names the destination chip coordinates.
#[test]
fn aliased_sparse_fanout_is_rejected_with_coordinates() {
    let w = Bci::default();
    let net = w.net();
    let weights = w.weights(42);
    let opts = Options {
        learning: w.learning(),
        rates: w.rates(),
        verify: false,
        aliased_sparse_fanout: true,
        ..Default::default()
    };
    let rep = compiler::compile(&net, &weights, &opts).expect("aliased compile");
    let r = verify(&rep.compiled, &net, opts.learning);
    assert!(!r.ok(), "aliased image passed verification");
    let e = r
        .errors
        .iter()
        .find(|e| matches!(e, VerifyError::SparseFanOutAliased { .. }))
        .unwrap_or_else(|| panic!("no aliasing diagnostic among: {r}"));
    let s = format!("{e}");
    assert!(s.contains("cc "), "diagnostic lacks chip coordinates: {s}");
    assert!(s.contains("alias"), "diagnostic does not name the defect: {s}");
}

/// Regression pin for the merged-sparse weight-slot bug: two identical
/// Lif sparse layers merge onto one NC; the second part's fan-in slots
/// must address weights at the part's cumulative base, not restart at 0.
#[test]
fn merged_sparse_parts_weight_slots_verify() {
    let lif = NeuronModel::Lif { tau: 0.9, vth: 1.0 };
    let blob = |input: usize, output: usize| -> Vec<f32> {
        (0..input * output)
            .map(|k| {
                if k % 3 == 0 {
                    0.0
                } else {
                    0.05 + (k % 7) as f32 * 0.01
                }
            })
            .collect()
    };
    let net = NetDef {
        name: "merged-sparse".into(),
        layers: vec![
            Layer::Input { size: 24 },
            Layer::Sparse { input: 24, output: 20, density: 0.7, neuron: lif },
            Layer::Sparse { input: 20, output: 16, density: 0.7, neuron: lif },
        ],
        skips: vec![],
        timesteps: 4,
    };
    let weights = vec![vec![], blob(24, 20), blob(20, 16)];
    let opts = Options { verify: false, ..Default::default() };
    let rep = compiler::compile(&net, &weights, &opts).expect("compile");
    let merged = rep
        .compiled
        .cores
        .iter()
        .any(|m| m.parts.len() >= 2 && m.parts.iter().skip(1).any(|&(li, ..)| {
            matches!(net.layers[li], Layer::Sparse { .. })
        }));
    assert!(merged, "net no longer exercises a merged sparse core");
    let r = verify(&rep.compiled, &net, opts.learning);
    assert!(
        !r.errors.iter().any(|e| matches!(e, VerifyError::SparseWeightSlot { .. })),
        "merged sparse weight slots regressed:\n{r}"
    );
    assert!(r.ok(), "{}\n{r}", r.summary());
    // And the default-on compile-time gate accepts it too.
    compiler::compile(&net, &weights, &Options::default()).expect("gated compile");
}

// ---- hand-corrupted images: one test per checker family ----------------

fn compiled_ecg() -> (NetDef, Options, Compiled) {
    let w = Ecg { heterogeneous: true };
    let (net, _weights, opts, compiled) = compile_one(&w, 42);
    (net, opts, compiled)
}

fn sorted_ccs(compiled: &Compiled) -> Vec<usize> {
    let mut ccs: Vec<usize> = compiled.config.ccs.keys().copied().collect();
    ccs.sort_unstable();
    ccs
}

#[test]
fn corrupt_fanout_index_is_caught() {
    let (net, opts, mut compiled) = compiled_ecg();
    let cc = sorted_ccs(&compiled)
        .into_iter()
        .find(|cc| !compiled.config.ccs[cc].tables.fanout_it.is_empty())
        .expect("a CC with fan-out");
    compiled.config.ccs.get_mut(&cc).unwrap().tables.fanout_it[0].index = u16::MAX;
    let r = verify(&compiled, &net, opts.learning);
    assert!(
        r.errors.iter().any(|e| matches!(e, VerifyError::FanOutIndexRange { .. })),
        "expected FanOutIndexRange:\n{r}"
    );
}

#[test]
fn corrupt_fanout_tag_is_caught() {
    let (net, opts, mut compiled) = compiled_ecg();
    let cc = sorted_ccs(&compiled)
        .into_iter()
        .find(|cc| !compiled.config.ccs[cc].tables.fanout_it.is_empty())
        .expect("a CC with fan-out");
    let ie = &mut compiled.config.ccs.get_mut(&cc).unwrap().tables.fanout_it[0];
    ie.tag += 1;
    let r = verify(&compiled, &net, opts.learning);
    assert!(
        r.errors.iter().any(|e| matches!(e, VerifyError::TagMismatch { .. })),
        "expected TagMismatch:\n{r}"
    );
}

#[test]
fn corrupt_route_off_mesh_is_caught() {
    let (net, opts, mut compiled) = compiled_ecg();
    let cc = sorted_ccs(&compiled)
        .into_iter()
        .find(|cc| !compiled.config.ccs[cc].tables.fanout_it.is_empty())
        .expect("a CC with fan-out");
    let ie = &mut compiled.config.ccs.get_mut(&cc).unwrap().tables.fanout_it[0];
    ie.mode = RouteMode::Unicast { x: 200, y: 0 };
    let r = verify(&compiled, &net, opts.learning);
    assert!(
        r.errors.iter().any(|e| matches!(e, VerifyError::RouteOffMesh { .. })),
        "expected RouteOffMesh:\n{r}"
    );
}

#[test]
fn corrupt_mem_region_is_caught() {
    let (net, opts, mut compiled) = compiled_ecg();
    let dw = compiled.data_words;
    let cc = sorted_ccs(&compiled)
        .into_iter()
        .find(|cc| compiled.config.ccs[cc].ncs.iter().any(Option::is_some))
        .expect("a CC with an NC");
    let img = compiled.config.ccs.get_mut(&cc).unwrap();
    let nc = img.ncs.iter_mut().flatten().next().unwrap();
    nc.mem.push(((dw - 8) as u16, vec![0u16; 64]));
    let r = verify(&compiled, &net, opts.learning);
    assert!(
        r.errors.iter().any(|e| matches!(e, VerifyError::MemRegion { .. })),
        "expected MemRegion:\n{r}"
    );
}

#[test]
fn corrupt_program_memory_operand_is_caught() {
    use taibai::isa::Opcode;
    let (net, opts, mut compiled) = compiled_ecg();
    let dw = compiled.data_words;
    let mut hit = false;
    'outer: for cc in sorted_ccs(&compiled) {
        let img = compiled.config.ccs.get_mut(&cc).unwrap();
        for nc in img.ncs.iter_mut().flatten() {
            if let Some(i) = nc
                .integ
                .code
                .iter_mut()
                .find(|i| matches!(i.op, Opcode::Ld | Opcode::St))
            {
                i.imm = dw as i32; // first address past the data memory
                hit = true;
                break 'outer;
            }
        }
    }
    assert!(hit, "no Ld/St instruction found to corrupt");
    let r = verify(&compiled, &net, opts.learning);
    assert!(
        r.errors.iter().any(|e| matches!(e, VerifyError::Isa { .. })),
        "expected Isa:\n{r}"
    );
}

#[test]
fn corrupt_readout_is_caught() {
    let (net, opts, mut compiled) = compiled_ecg();
    let key = *compiled.readout.keys().next().expect("a readout entry");
    compiled.readout.remove(&key);
    let r = verify(&compiled, &net, opts.learning);
    assert!(
        r.errors
            .iter()
            .any(|e| matches!(e, VerifyError::HostMap { kind: "readout", .. })),
        "expected readout HostMap:\n{r}"
    );
}
