//! Integration coverage for the `taibai::api` Session pipeline:
//! * every packaged workload builds a `Session` on both backends;
//! * a fast-vs-detailed parity smoke test (the two engines must agree
//!   on activity/energy within the documented band);
//! * `run_batch` returns exactly what sequential `run` calls return.

use taibai::api::workloads::{Bci, Ecg, Shd};
use taibai::api::{evaluate, Backend, ExecOptions, Sample, Taibai, Workload};
use taibai::energy::EnergyModel;
use taibai::model::{Layer, NetDef, NeuronModel};

#[test]
fn all_workloads_build_sessions_on_both_backends() {
    let workloads: Vec<Box<dyn Workload>> = vec![
        Box::new(Ecg { heterogeneous: true }),
        Box::new(Shd { dendrites: true }),
        Box::new(Bci::default()),
    ];
    for w in &workloads {
        for backend in [Backend::Detailed, Backend::Analytic] {
            let session = w
                .session(backend, 42)
                .unwrap_or_else(|e| panic!("{} on {backend}: {e}", w.name()));
            assert_eq!(session.backend(), backend);
            assert!(
                session.info().used_cores >= 1,
                "{} on {backend}: no cores",
                w.name()
            );
        }
    }
}

#[test]
fn same_workload_runs_on_both_backends() {
    // one flag flips the engine; the workload protocol is unchanged
    let w = Ecg { heterogeneous: true };
    for backend in [Backend::Detailed, Backend::Analytic] {
        let mut session = w.session(backend, 7).unwrap();
        let r = evaluate(&w, &mut session, 1, 7).unwrap();
        let m = session.metrics();
        assert!(m.sops > 0, "{backend}: no synaptic work recorded");
        assert!(m.fps > 0.0 && m.power_w > 0.0, "{backend}: empty metrics");
        if backend == Backend::Detailed {
            assert!(r.accuracy >= 0.0 && r.accuracy <= 1.0);
            assert!(r.spikes_per_sample > 0.0);
        }
    }
}

#[test]
fn fast_vs_detailed_parity_on_a_small_net() {
    // silent hidden layer makes the detailed SOP count deterministic:
    // every input spike costs exactly `output` accumulates
    let mut net = NetDef::new("parity", 30);
    net.layers.push(Layer::Input { size: 24 });
    net.layers.push(Layer::Fc {
        input: 24,
        output: 48,
        neuron: NeuronModel::Lif { tau: 0.5, vth: 50.0 },
    });
    let w1 = vec![0.05f32; 24 * 48];
    let sample = Sample::poisson(24, 30, 0.3, 5);
    let measured = sample.input_rate(24);

    let mut detailed = Taibai::new(net.clone())
        .weights(vec![vec![], w1])
        .build()
        .unwrap();
    detailed.run(&sample).unwrap();

    let mut fast = Taibai::new(net)
        .rates(vec![measured, 0.0])
        .exec(ExecOptions {
            backend: Backend::Analytic,
            ..ExecOptions::default()
        })
        .build()
        .unwrap();
    fast.run(&sample).unwrap();

    let da = detailed.activity();
    let fa = fast.activity();
    assert!(da.nc.sops > 0);
    let sop_err =
        (fa.nc.sops as f64 - da.nc.sops as f64).abs() / da.nc.sops as f64;
    assert!(sop_err < 0.05, "SOP divergence {sop_err}: {} vs {}", da.nc.sops, fa.nc.sops);

    let em = EnergyModel::default();
    let de = em.energy(&da).dynamic_j();
    let fe = em.energy(&fa).dynamic_j();
    let e_err = (fe - de).abs() / de;
    assert!(e_err < 0.6, "energy divergence {e_err}: {de} vs {fe}");
}

#[test]
fn run_batch_equals_sequential_runs() {
    let w = Shd { dendrites: true };
    let data = w.dataset(6, 3);

    let mut seq = w.session(Backend::Detailed, 3).unwrap();
    let mut expected = Vec::new();
    for s in &data {
        expected.push(seq.run(s).unwrap());
    }

    let mut par = w.session(Backend::Detailed, 3).unwrap();
    let got = par.run_batch(&data).unwrap();

    assert_eq!(got.len(), expected.len());
    for (i, (g, e)) in got.iter().zip(&expected).enumerate() {
        assert_eq!(g.outputs, e.outputs, "sample {i}: outputs diverged");
        assert_eq!(g.spikes, e.spikes, "sample {i}: spike counts diverged");
    }
    assert_eq!(par.samples_run(), data.len() as u64);
    // batch workers' activity is folded into the session totals
    assert_eq!(par.activity().nc.sops, seq.activity().nc.sops);
}

#[test]
fn run_batch_on_analytic_backend_is_sequential_but_equal() {
    let w = Ecg { heterogeneous: true };
    let data = w.dataset(3, 9);
    let mut a = w.session(Backend::Analytic, 9).unwrap();
    let batch = a.run_batch(&data).unwrap();
    let mut b = w.session(Backend::Analytic, 9).unwrap();
    let seq: Vec<_> = data.iter().map(|s| b.run(s).unwrap()).collect();
    for (x, y) in batch.iter().zip(&seq) {
        assert_eq!(x.spikes, y.spikes);
        assert_eq!(x.packets, y.packets);
    }
}

#[test]
fn learning_session_fine_tunes_through_the_api() {
    // the BCI protocol end-to-end: build with learning, prepare
    // (on-chip fine-tune), decode — all through Session calls
    let w = Bci { subpaths: 8, day: 2 };
    let mut session = w.session(Backend::Detailed, 11).unwrap();
    let r = evaluate(&w, &mut session, 4, 11).unwrap();
    assert!(r.accuracy >= 0.0 && r.accuracy <= 1.0);
    // 32 fine-tune runs + 4 eval runs all went through the session
    assert!(session.samples_run() >= 36, "{}", session.samples_run());
}
