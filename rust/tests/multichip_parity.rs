//! Multi-chip sharded execution parity.
//!
//! The contract of `compiler::shard` + `coordinator::MultiChipDeployment`
//! is that sharding is *invisible* to the model: a network cut across N
//! lockstep dies produces bit-identical readout rows to the single-die
//! engine, because cross-die spikes travel with exactly the one-timestep
//! latency (and the same ascending-source delivery order) the on-die NoC
//! provides.
//!
//! Both engines are built with `sa_iters(0)` so the deterministic zigzag
//! placement isolates the sharding transform itself. Invariants come in
//! three tiers:
//!
//! * **always** — readout rows bit-exact; per-neuron-core activity
//!   (SOPs, NC activations, lockstep timesteps) equal. These are
//!   placement-invariant: sharding may regroup cores into different CCs,
//!   but every neuron sees the same events in the same order. (Raw SEND
//!   counts are *not* here: a readout core sharing a CC with earlier
//!   layers emits its zero-valued rows from step 0, so co-residency
//!   changes shift `spikes_out` without changing any emitted value.)
//! * **routing** (cut preserves each layer's CC grouping) — minted spike
//!   packets, routed packets, and table reads also equal.
//! * **full** (cut falls exactly on a CC boundary, so NC co-residency is
//!   unchanged) — the entire `NcStats` block matches: instruction counts,
//!   wakeups, and SEND counts included.

use taibai::api::workloads::{Bci, Ecg, Shd, Workload};
use taibai::api::{Backend, ExecOptions, Sample, Session, ShardStrategy, Taibai};
use taibai::compiler::Objective;
use taibai::model;

/// Run-ahead depths every parity tier is pinned at (1 = parallel
/// lockstep, 8 > any tested die count's natural lag).
const DEPTHS: [usize; 3] = [1, 2, 8];

fn build_depth(
    w: &dyn Workload,
    backend: Backend,
    objective: Objective,
    seed: u64,
    strategy: ShardStrategy,
    depth: usize,
) -> Session {
    Taibai::new(w.net())
        .weights(w.weights(seed))
        .rates(w.rates())
        .learning(w.learning())
        .exec(ExecOptions {
            backend,
            objective,
            strategy,
            sa_iters: 0,
            pipeline_depth: depth,
            ..ExecOptions::default()
        })
        .build()
        .expect("compile")
}

fn build(
    w: &dyn Workload,
    backend: Backend,
    objective: Objective,
    seed: u64,
    strategy: ShardStrategy,
) -> Session {
    build_depth(w, backend, objective, seed, strategy, 0)
}

/// Run `samples` dataset samples through both engines and pin the
/// agreed invariant tiers. The `routing`/`full` tiers describe cuts
/// that preserve the single-die CC grouping, which only the
/// `Contiguous` strategy guarantees for every workload; `MinCut` cases
/// pin the always-tier (rows + placement-invariant counters).
fn assert_parity_with(
    w: &dyn Workload,
    chips: usize,
    objective: Objective,
    samples: usize,
    routing: bool,
    full: bool,
    strategy: ShardStrategy,
) {
    let seed = 11;
    let mut single = build(w, Backend::Detailed, objective, seed, strategy);
    let mut sharded = build(w, Backend::Sharded { chips }, objective, seed, strategy);
    assert_eq!(single.info().chips, 1);
    assert_eq!(sharded.info().chips, chips, "forced die count not honored");
    assert_eq!(
        single.info().used_cores,
        sharded.info().used_cores,
        "sharding must not change the core count"
    );

    let data = w.dataset(samples, seed);
    let mut reference = Vec::new();
    for (si, s) in data.iter().take(samples).enumerate() {
        let a = single.run(s).expect("single-die run");
        let b = sharded.run(s).expect("sharded run");
        assert_eq!(
            a.outputs, b.outputs,
            "{} x{chips}: sample {si} readout rows diverged",
            w.name()
        );
        if routing {
            assert_eq!(
                a.spikes, b.spikes,
                "{} x{chips}: sample {si} minted spike count diverged",
                w.name()
            );
            assert_eq!(
                a.packets, b.packets,
                "{} x{chips}: sample {si} routed packet count diverged",
                w.name()
            );
        }
        reference.push(a);
    }

    let aa = single.activity();
    let bb = sharded.activity();
    let tag = format!("{} x{chips}", w.name());
    assert_eq!(aa.nc.sops, bb.nc.sops, "{tag}: SOPs");
    assert_eq!(aa.activations, bb.activations, "{tag}: NC activations");
    assert_eq!(aa.timesteps, bb.timesteps, "{tag}: lockstep timesteps");
    assert!(bb.link_traversals > 0, "{tag}: dies never talked");
    if routing {
        assert_eq!(aa.packets, bb.packets, "{tag}: routed packets");
        assert_eq!(aa.dt_reads, bb.dt_reads, "{tag}: DT reads");
        assert_eq!(aa.it_reads, bb.it_reads, "{tag}: IT reads");
    }
    if full {
        assert_eq!(aa.nc, bb.nc, "{tag}: full NC stats block");
    }
    // the sharded engine's bridge accounting is self-consistent: the
    // per-edge matrix sums to the aggregate remote-packet counter
    let bridge = sharded
        .telemetry()
        .bridge
        .expect("sharded backends expose per-edge bridge counters");
    assert_eq!(bridge.len(), chips);
    let total: u64 = bridge.iter().flatten().sum();
    assert_eq!(total, bb.remote_packets, "{tag}: bridge matrix vs aggregate");
    for (i, row) in bridge.iter().enumerate() {
        assert_eq!(row[i], 0, "{tag}: die {i} bridged to itself");
    }
    assert_eq!(aa.remote_packets, 0, "{tag}: single die minted remote packets");

    // pipelined stepper: bounded run-ahead must be invisible at every
    // depth — same rows, same activity, same per-edge bridge matrix
    for depth in DEPTHS {
        let mut piped =
            build_depth(w, Backend::Sharded { chips }, objective, seed, strategy, depth);
        for (si, (s, a)) in data.iter().take(samples).zip(&reference).enumerate() {
            let p = piped.run(s).expect("pipelined run");
            assert_eq!(
                p.outputs, a.outputs,
                "{tag} depth {depth}: sample {si} rows diverged"
            );
            if routing {
                assert_eq!(p.spikes, a.spikes, "{tag} depth {depth}: sample {si} spikes");
                assert_eq!(
                    p.packets, a.packets,
                    "{tag} depth {depth}: sample {si} packets"
                );
            }
        }
        let t = piped.telemetry();
        assert_eq!(t.activity.nc.sops, aa.nc.sops, "{tag} depth {depth}: SOPs");
        assert_eq!(
            t.activity.activations, aa.activations,
            "{tag} depth {depth}: NC activations"
        );
        assert_eq!(
            t.activity.timesteps, aa.timesteps,
            "{tag} depth {depth}: timesteps"
        );
        assert_eq!(
            t.bridge.as_ref(),
            Some(&bridge),
            "{tag} depth {depth}: bridge matrix diverged from sequential"
        );
        let ps = t.pipeline.expect("pipelined mode exposes PipelineStats");
        assert_eq!(ps.depth, depth, "{tag}: depth echoed back");
        let claims: u64 = ps.lag_histogram.iter().sum();
        assert!(claims > 0, "{tag} depth {depth}: lag histogram never bumped");
        assert!(
            ps.lag_histogram.len() <= depth,
            "{tag} depth {depth}: lag {} exceeded the run-ahead bound",
            ps.lag_histogram.len()
        );
    }
}

/// Contiguous-strategy wrapper (the tier expectations below were
/// calibrated for contiguous cuts).
fn assert_parity(
    w: &dyn Workload,
    chips: usize,
    objective: Objective,
    samples: usize,
    routing: bool,
    full: bool,
) {
    assert_parity_with(
        w,
        chips,
        objective,
        samples,
        routing,
        full,
        ShardStrategy::Contiguous,
    );
}

#[test]
fn ecg_two_way_parity() {
    // 2 cores on one CC → core-granularity cut: co-residency changes
    // (full=false) but each layer still occupies one CC (routing=true)
    assert_parity(
        &Ecg { heterogeneous: true },
        2,
        Objective::MinCores,
        1,
        true,
        false,
    );
}

#[test]
fn shd_two_way_parity() {
    // 9 cores = CC0 (8 hidden) + CC1 (readout): the cut falls exactly on
    // the CC boundary, so every counter must match (full=true)
    assert_parity(&Shd { dendrites: true }, 2, Objective::MinCores, 3, true, true);
}

#[test]
fn bci_two_way_parity() {
    // merged sparse sub-paths on die 0, learning head on die 1
    assert_parity(&Bci { subpaths: 8, day: 2 }, 2, Objective::MinCores, 2, true, false);
}

#[test]
fn ecg_four_way_parity() {
    // spread the recurrent layer over several dies: recurrence now
    // crosses the bridge both forward and backward every step
    assert_parity(
        &Ecg { heterogeneous: true },
        4,
        Objective::Balanced(16),
        1,
        false,
        false,
    );
}

#[test]
fn shd_four_way_parity() {
    assert_parity(&Shd { dendrites: true }, 4, Objective::MinCores, 2, false, false);
}

#[test]
fn bci_four_way_parity() {
    // Balanced(32) splits each 64-neuron sparse stage in two, yielding
    // enough cores (5) to spread over four dies
    assert_parity(
        &Bci { subpaths: 8, day: 2 },
        4,
        Objective::Balanced(32),
        2,
        false,
        false,
    );
}

#[test]
fn sharded_learning_matches_single_die() {
    // the BCI on-chip fine-tune protocol, lockstep across 2 dies: error
    // injection, the learning FIRE sweep, and the resulting weight
    // updates must leave every engine — sequential and pipelined at
    // each depth — with readouts identical to the single-die reference
    let w = Bci { subpaths: 8, day: 4 };
    let data = w.dataset(4, 7);
    let err = [0.5f32, -0.25, 0.125, -0.5];
    let probe = &w.dataset(4, 9)[0];

    let mut single = build(
        &w,
        Backend::Detailed,
        Objective::MinCores,
        7,
        ShardStrategy::Contiguous,
    );
    let mut pre = Vec::new();
    for s in data.iter().take(2) {
        pre.push(single.run(s).expect("single").outputs);
        single.learn_step(&err).expect("single learn");
    }
    let post = single.run(probe).expect("single probe").outputs;

    for depth in [0, DEPTHS[0], DEPTHS[1], DEPTHS[2]] {
        let mut sharded = build_depth(
            &w,
            Backend::Sharded { chips: 2 },
            Objective::MinCores,
            7,
            ShardStrategy::Contiguous,
            depth,
        );
        for (si, s) in data.iter().take(2).enumerate() {
            let rb = sharded.run(s).expect("sharded");
            assert_eq!(
                rb.outputs, pre[si],
                "depth {depth}: pre-learning sample {si}"
            );
            sharded.learn_step(&err).expect("sharded learn");
        }
        assert_eq!(
            sharded.run(probe).expect("sharded probe").outputs,
            post,
            "depth {depth}: post-learning readouts diverged: weight \
             updates not bit-identical"
        );
    }
}

// ---------------------------------------------------------------------
// MinCut strategy: the topology-aware cut must stay invisible to the
// model — rows bit-identical, placement-invariant counters equal —
// while shipping no more bridge traffic than the contiguous baseline.
// ---------------------------------------------------------------------

#[test]
fn ecg_two_way_mincut_parity() {
    assert_parity_with(
        &Ecg { heterogeneous: true },
        2,
        Objective::MinCores,
        1,
        false,
        false,
        ShardStrategy::MinCut,
    );
}

#[test]
fn shd_two_way_mincut_parity() {
    // 9 cores = 2 CC groups on 2 dies: the balanced capacity forces the
    // same CC-boundary cut as the contiguous split, so even the full
    // NC-stats tier must hold
    assert_parity_with(
        &Shd { dendrites: true },
        2,
        Objective::MinCores,
        2,
        true,
        true,
        ShardStrategy::MinCut,
    );
}

#[test]
fn bci_two_way_mincut_parity() {
    assert_parity_with(
        &Bci { subpaths: 8, day: 2 },
        2,
        Objective::MinCores,
        2,
        false,
        false,
        ShardStrategy::MinCut,
    );
}

#[test]
fn ecg_four_way_mincut_parity() {
    // recurrent traffic now steers the cut: hidden cores cluster, the
    // readout follows its sources — rows must not notice
    assert_parity_with(
        &Ecg { heterogeneous: true },
        4,
        Objective::Balanced(16),
        1,
        false,
        false,
        ShardStrategy::MinCut,
    );
}

#[test]
fn shd_four_way_mincut_parity() {
    assert_parity_with(
        &Shd { dendrites: true },
        4,
        Objective::MinCores,
        2,
        false,
        false,
        ShardStrategy::MinCut,
    );
}

#[test]
fn bci_four_way_mincut_parity() {
    assert_parity_with(
        &Bci { subpaths: 8, day: 2 },
        4,
        Objective::Balanced(32),
        2,
        false,
        false,
        ShardStrategy::MinCut,
    );
}

#[test]
fn mincut_learning_matches_single_die() {
    // the BCI on-chip fine-tune under the topology-aware cut: error
    // injection, learning sweeps, and weight updates bit-identical,
    // sequentially and at every pipelined depth
    let w = Bci { subpaths: 8, day: 4 };
    let data = w.dataset(4, 13);
    let err = [0.25f32, -0.5, 0.375, -0.125];
    let probe = &w.dataset(4, 17)[0];

    let mut single = build(
        &w,
        Backend::Detailed,
        Objective::MinCores,
        13,
        ShardStrategy::MinCut,
    );
    let mut pre = Vec::new();
    for s in data.iter().take(2) {
        pre.push(single.run(s).expect("single").outputs);
        single.learn_step(&err).expect("single learn");
    }
    let post = single.run(probe).expect("single probe").outputs;

    for depth in [0, DEPTHS[0], DEPTHS[1], DEPTHS[2]] {
        let mut sharded = build_depth(
            &w,
            Backend::Sharded { chips: 2 },
            Objective::MinCores,
            13,
            ShardStrategy::MinCut,
            depth,
        );
        for (si, s) in data.iter().take(2).enumerate() {
            let rb = sharded.run(s).expect("sharded");
            assert_eq!(
                rb.outputs, pre[si],
                "depth {depth}: pre-learning sample {si}"
            );
            sharded.learn_step(&err).expect("sharded learn");
        }
        assert_eq!(
            sharded.run(probe).expect("sharded probe").outputs,
            post,
            "depth {depth}: post-learning readouts diverged under MinCut"
        );
    }
}

#[test]
fn mincut_with_serdes_sa_keeps_rows_identical() {
    // the full tentpole path — MinCut cut points *plus* SerDes-aware SA
    // over the multi-die slot space — must still be invisible to the
    // model's outputs and placement-invariant counters
    let w = Shd { dendrites: true };
    let seed = 11;
    let mut single = build(
        &w,
        Backend::Detailed,
        Objective::MinCores,
        seed,
        ShardStrategy::MinCut,
    );
    let mut sharded = Taibai::new(w.net())
        .weights(w.weights(seed))
        .rates(w.rates())
        .exec(ExecOptions {
            backend: Backend::Sharded { chips: 2 },
            strategy: ShardStrategy::MinCut,
            sa_iters: 1500,
            ..ExecOptions::default()
        })
        .build()
        .expect("compile");
    for (si, s) in w.dataset(2, seed).iter().take(2).enumerate() {
        assert_eq!(
            single.run(s).expect("single").outputs,
            sharded.run(s).expect("sharded").outputs,
            "sample {si}: SerDes-aware SA placement changed the readout"
        );
    }
    let (aa, bb) = (single.activity(), sharded.activity());
    assert_eq!(aa.nc.sops, bb.nc.sops, "SOPs");
    assert_eq!(aa.activations, bb.activations, "NC activations");
}

#[test]
fn mincut_ships_no_more_bridge_traffic_than_contiguous() {
    // the tentpole's win, pinned at test level on the 4-way SHD shard:
    // both the compiler's cut estimate and the measured bridge counters
    // must come out strictly lower under MinCut
    let w = Shd { dendrites: true };
    let seed = 42;
    let data = w.dataset(2, seed);
    let mut remote = Vec::new();
    let mut estimates = Vec::new();
    for strategy in [ShardStrategy::Contiguous, ShardStrategy::MinCut] {
        let mut s = build(&w, Backend::Sharded { chips: 4 }, Objective::MinCores, seed, strategy);
        estimates.push(s.info().cut_traffic);
        for sample in data.iter().take(2) {
            s.run(sample).expect("run");
        }
        remote.push(s.activity().remote_packets);
    }
    assert!(
        estimates[1] < estimates[0],
        "MinCut's cut estimate not lower: {} vs {}",
        estimates[1],
        estimates[0]
    );
    assert!(
        remote[1] < remote[0],
        "MinCut shipped no fewer remote packets: {} vs {}",
        remote[1],
        remote[0]
    );
}

#[test]
fn over_capacity_net_runs_end_to_end_sharded() {
    // > 1056 neuron cores: the single-die compiler refuses this net with
    // TooManyCores; `Backend::Detailed` now falls back to the sharded
    // pipeline instead of dead-ending
    let net = model::wide_fc_net(8, 600, 2, 4);
    let weights = model::wide_fc_weights(&net, 3);
    let mut session = Taibai::new(net)
        .weights(weights)
        .exec(ExecOptions {
            objective: Objective::Balanced(1),
            merge: false,
            sa_iters: 0,
            ..ExecOptions::default()
        })
        .build()
        .expect("over-capacity net must compile via the sharded fallback");
    assert!(
        matches!(session.backend(), Backend::Sharded { .. }),
        "expected the sharded fallback, got {}",
        session.backend()
    );
    assert!(session.info().chips >= 2, "{} dies", session.info().chips);
    assert!(
        session.info().used_cores > 1056,
        "net should exceed one die: {} cores",
        session.info().used_cores
    );

    let run = session.run(&Sample::poisson(8, 8, 0.5, 5)).expect("run");
    assert_eq!(run.outputs.len(), 8);
    assert!(run.spikes > 0, "nothing spiked across the dies");
    assert!(
        run.outputs.iter().any(|row| row.iter().any(|&v| v != 0.0)),
        "readout never received a value across the bridge"
    );
    let m = session.metrics();
    assert!(m.sops > 0 && m.power_w > 0.0);
    assert_eq!(m.chips, session.info().chips);
}

#[test]
fn sharded_run_batch_matches_sequential() {
    // run_batch forks a multi-die deployment per worker (Arc-shared
    // image) and must return the same results in order
    let w = Shd { dendrites: true };
    let data = w.dataset(4, 21);
    let mut seq = build(
        &w,
        Backend::Sharded { chips: 2 },
        Objective::MinCores,
        21,
        ShardStrategy::MinCut,
    );
    let mut expected = Vec::new();
    for s in data.iter().take(4) {
        expected.push(seq.run(s).expect("sequential"));
    }
    let mut par = build(
        &w,
        Backend::Sharded { chips: 2 },
        Objective::MinCores,
        21,
        ShardStrategy::MinCut,
    );
    let got = par.run_batch(&data[..4.min(data.len())]).expect("batch");
    assert_eq!(got.len(), expected.len());
    for (g, e) in got.iter().zip(&expected) {
        assert_eq!(g.outputs, e.outputs);
        assert_eq!(g.spikes, e.spikes);
    }
    assert_eq!(par.activity().nc.sops, seq.activity().nc.sops);
}
