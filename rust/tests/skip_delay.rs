//! Skip-connection delays, end to end through the compiler and the
//! chip (ROADMAP item: codegen now emits `FanOutIE::delay > 0`).
//!
//! A skip `from -> to` crosses `to - from - 1` intermediate layers, so
//! its spikes must be held exactly that many timesteps (§III-D.6) to
//! land together with the direct path. The timing test pins that
//! alignment on a compiled chain; the sharded test pins that a delayed
//! edge forced across a die boundary *compiles and runs* (the bridge
//! orders releases by their tagged `release_step`, so the former
//! `CrossDieDelay` refusal is lifted) and holds exactly its delay —
//! bit-identical to the single-die reference, in sequential and
//! pipelined stepping alike.

use taibai::api::{Backend, ExecOptions, Sample, ShardStrategy, Taibai};
use taibai::datasets::SpikeSample;
use taibai::model::{self, Layer, NetDef, NeuronModel, Skip};
use taibai::topology::RouteMode;

/// Input(2) → Fc(2→2 LIF) → Fc(2→2 LIF) → Fc(2→2 readout), diagonal
/// weights strong enough that a channel-0 spike propagates every hop.
fn chain_net(skip: bool) -> (NetDef, Vec<Vec<f32>>) {
    let lif = NeuronModel::Lif { tau: 0.5, vth: 1.0 };
    let mut net = NetDef::new("skip-chain", 10);
    net.layers.push(Layer::Input { size: 2 });
    net.layers.push(Layer::Fc { input: 2, output: 2, neuron: lif });
    net.layers.push(Layer::Fc { input: 2, output: 2, neuron: lif });
    net.layers.push(Layer::Fc {
        input: 2,
        output: 2,
        neuron: NeuronModel::Readout { tau: 0.9 },
    });
    if skip {
        // crosses layer 2 → delay 1
        net.skips.push(Skip { from: 1, to: 3 });
    }
    let diag = |v: f32| vec![v, 0.0, 0.0, v];
    (net, vec![vec![], diag(1.5), diag(1.5), diag(1.0)])
}

fn burst_sample() -> Sample {
    // channel 0 fires at t = 0 only
    let mut spikes = vec![vec![]; 10];
    spikes[0] = vec![0u16];
    Sample::Spikes(SpikeSample {
        spikes,
        labels: vec![0],
    })
}

#[test]
fn codegen_delay_holds_the_skip_until_the_direct_path_lands() {
    let (net, w) = chain_net(true);
    let mut with_skip = Taibai::new(net).weights(w).build().expect("compile");
    let run = with_skip.run(&burst_sample()).expect("run");

    let (net, w) = chain_net(false);
    let mut baseline = Taibai::new(net).weights(w).build().expect("compile");
    let base = baseline.run(&burst_sample()).expect("run");

    // 2-hop pipeline latency: nothing reaches the readout before t = 2.
    // If codegen had dropped the delay to 0, the skip spike would wake
    // the readout alone at t = 1.
    for t in 0..2 {
        assert!(
            run.outputs[t].iter().all(|&v| v == 0.0),
            "t={t}: skip spike arrived early (delay not emitted): {:?}",
            run.outputs[t]
        );
    }
    // At t = 2 the delayed skip and the direct path land together: the
    // readout integrates both unit-weight contributions.
    let skip_v = run.outputs[2][0];
    let base_v = base.outputs[2][0];
    assert!(base_v > 0.5, "direct path never arrived: {base_v}");
    assert!(
        skip_v > base_v * 1.5,
        "skip contribution missing at t=2: {skip_v} vs direct-only {base_v}"
    );
    // one extra held-then-released spike relative to the plain chain
    assert_eq!(run.spikes, base.spikes + 1, "skip spike not minted");
}

#[test]
fn delayed_skip_across_dies_compiles_and_holds_its_delay() {
    // Wide-FC over 2 forced dies, contiguous cut: layer 1 lands on die
    // 0 and the skip target (layer 3) on die 1, so the delayed edge
    // crosses the host bridge. This used to be a typed refusal
    // (`CompileError::CrossDieDelay`); the bridge now tags every
    // egressed packet with its absolute release step, so delay-line
    // releases order correctly across dies and the path just works.
    let mut net = model::wide_fc_net(8, 600, 2, 4);
    net.skips.push(Skip { from: 1, to: 3 });
    let weights = model::wide_fc_weights(&net, 3);
    let sample = Sample::poisson(8, 8, 0.5, 7);

    let sharded_opts = |depth: usize| ExecOptions {
        backend: Backend::Sharded { chips: 2 },
        strategy: ShardStrategy::Contiguous,
        merge: false,
        sa_iters: 0,
        pipeline_depth: depth,
        ..ExecOptions::default()
    };

    // the compiled 2-die image really carries a delayed remote edge
    let image = {
        let opts = taibai::compiler::Options {
            strategy: ShardStrategy::Contiguous,
            merge: false,
            sa_iters: 0,
            ..Default::default()
        };
        taibai::compiler::compile_sharded(&net, &weights, &opts, 2)
            .expect("delayed cross-die skip must compile")
            .sharded
    };
    let delayed_remote = image
        .chips
        .iter()
        .flat_map(|img| img.config.ccs.values())
        .flat_map(|cc| cc.tables.fanout_it.iter())
        .any(|ie| ie.delay > 0 && matches!(ie.mode, RouteMode::Remote { .. }));
    assert!(
        delayed_remote,
        "expected a delayed Remote fan-out IE in the 2-die image"
    );

    // single-die reference (same net, auto-sized to one chip)
    let mut single = Taibai::new(net.clone())
        .weights(weights.clone())
        .exec(ExecOptions {
            merge: false,
            sa_iters: 0,
            ..ExecOptions::default()
        })
        .build()
        .expect("single-die reference");
    assert_eq!(single.info().chips, 1);
    let reference = single.run(&sample).expect("single-die run");

    // sequential 2-die run: bit-identical rows, and the skip actually
    // crossed the bridge
    let mut seq = Taibai::new(net.clone())
        .weights(weights.clone())
        .exec(sharded_opts(0))
        .build()
        .expect("2-die sequential build");
    let seq_run = seq.run(&sample).expect("2-die sequential run");
    assert_eq!(
        seq_run.outputs, reference.outputs,
        "2-die rows must match the single-die reference exactly"
    );
    assert_eq!(seq_run.spikes, reference.spikes);
    let bridge = seq.telemetry().bridge.expect("bridge matrix");
    let crossed: u64 = bridge.iter().flatten().sum();
    assert!(crossed > 0, "no packets crossed the bridge: {bridge:?}");

    // pipelined runs at several depths: same bits again
    for depth in [1usize, 2, 8] {
        let mut piped = Taibai::new(net.clone())
            .weights(weights.clone())
            .exec(sharded_opts(depth))
            .build()
            .unwrap_or_else(|e| panic!("depth-{depth} build: {e}"));
        let run = piped
            .run(&sample)
            .unwrap_or_else(|e| panic!("depth-{depth} run: {e}"));
        assert_eq!(
            run.outputs, reference.outputs,
            "pipelined depth {depth} diverged from the reference"
        );
        assert_eq!(run.spikes, reference.spikes, "depth {depth} spike count");
    }
}

#[test]
fn single_die_build_of_the_same_skipped_net_compiles() {
    // sanity anchor for the cross-die test above: the identical net on
    // one (auto-sized) die deploys fine
    let mut net = model::wide_fc_net(8, 600, 2, 4);
    net.skips.push(Skip { from: 1, to: 3 });
    let weights = model::wide_fc_weights(&net, 3);
    let session = Taibai::new(net)
        .weights(weights)
        .exec(ExecOptions {
            merge: false,
            sa_iters: 0,
            ..ExecOptions::default()
        })
        .build()
        .expect("single-die delayed skip must compile");
    assert_eq!(session.info().chips, 1);
}
