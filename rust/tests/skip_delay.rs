//! Skip-connection delays, end to end through the compiler and the
//! chip (ROADMAP item: codegen now emits `FanOutIE::delay > 0`).
//!
//! A skip `from -> to` crosses `to - from - 1` intermediate layers, so
//! its spikes must be held exactly that many timesteps (§III-D.6) to
//! land together with the direct path. The timing test pins that
//! alignment on a compiled chain; the sharded test pins that a delayed
//! edge forced across a die boundary is a *typed* refusal
//! (`CompileError::CrossDieDelay`) instead of a silently dropped delay.

use taibai::api::{Backend, CompileError, Sample, ShardStrategy, Taibai};
use taibai::datasets::SpikeSample;
use taibai::model::{self, Layer, NetDef, NeuronModel, Skip};

/// Input(2) → Fc(2→2 LIF) → Fc(2→2 LIF) → Fc(2→2 readout), diagonal
/// weights strong enough that a channel-0 spike propagates every hop.
fn chain_net(skip: bool) -> (NetDef, Vec<Vec<f32>>) {
    let lif = NeuronModel::Lif { tau: 0.5, vth: 1.0 };
    let mut net = NetDef::new("skip-chain", 10);
    net.layers.push(Layer::Input { size: 2 });
    net.layers.push(Layer::Fc { input: 2, output: 2, neuron: lif });
    net.layers.push(Layer::Fc { input: 2, output: 2, neuron: lif });
    net.layers.push(Layer::Fc {
        input: 2,
        output: 2,
        neuron: NeuronModel::Readout { tau: 0.9 },
    });
    if skip {
        // crosses layer 2 → delay 1
        net.skips.push(Skip { from: 1, to: 3 });
    }
    let diag = |v: f32| vec![v, 0.0, 0.0, v];
    (net, vec![vec![], diag(1.5), diag(1.5), diag(1.0)])
}

fn burst_sample() -> Sample {
    // channel 0 fires at t = 0 only
    let mut spikes = vec![vec![]; 10];
    spikes[0] = vec![0u16];
    Sample::Spikes(SpikeSample {
        spikes,
        labels: vec![0],
    })
}

#[test]
fn codegen_delay_holds_the_skip_until_the_direct_path_lands() {
    let (net, w) = chain_net(true);
    let mut with_skip = Taibai::new(net).weights(w).build().expect("compile");
    let run = with_skip.run(&burst_sample()).expect("run");

    let (net, w) = chain_net(false);
    let mut baseline = Taibai::new(net).weights(w).build().expect("compile");
    let base = baseline.run(&burst_sample()).expect("run");

    // 2-hop pipeline latency: nothing reaches the readout before t = 2.
    // If codegen had dropped the delay to 0, the skip spike would wake
    // the readout alone at t = 1.
    for t in 0..2 {
        assert!(
            run.outputs[t].iter().all(|&v| v == 0.0),
            "t={t}: skip spike arrived early (delay not emitted): {:?}",
            run.outputs[t]
        );
    }
    // At t = 2 the delayed skip and the direct path land together: the
    // readout integrates both unit-weight contributions.
    let skip_v = run.outputs[2][0];
    let base_v = base.outputs[2][0];
    assert!(base_v > 0.5, "direct path never arrived: {base_v}");
    assert!(
        skip_v > base_v * 1.5,
        "skip contribution missing at t=2: {skip_v} vs direct-only {base_v}"
    );
    // one extra held-then-released spike relative to the plain chain
    assert_eq!(run.spikes, base.spikes + 1, "skip spike not minted");
}

#[test]
fn delayed_skip_across_dies_is_a_typed_compile_error() {
    // Wide-FC over 2 forced dies, contiguous cut: layer 1 lands on die
    // 0 and the skip target (layer 3) on die 1, so the delayed edge
    // would have to cross the host bridge — which has no ordering rule
    // for delay-line releases.
    let mut net = model::wide_fc_net(8, 600, 2, 4);
    net.skips.push(Skip { from: 1, to: 3 });
    let weights = model::wide_fc_weights(&net, 3);
    let built = Taibai::new(net)
        .weights(weights)
        .backend(Backend::Sharded { chips: 2 })
        .shard_strategy(ShardStrategy::Contiguous)
        .merge(false)
        .sa_iters(0)
        .build();
    match built {
        Err(CompileError::CrossDieDelay {
            from: 1,
            to: 3,
            delay: 1,
        }) => {}
        Err(other) => panic!("expected CrossDieDelay, got {other:?}"),
        Ok(_) => panic!("delayed cross-die skip must be refused"),
    }
}

#[test]
fn single_die_build_of_the_same_skipped_net_compiles() {
    // the refusal above is about the cut, not the skip: the identical
    // net on one (auto-sized) die deploys fine
    let mut net = model::wide_fc_net(8, 600, 2, 4);
    net.skips.push(Skip { from: 1, to: 3 });
    let weights = model::wide_fc_weights(&net, 3);
    let session = Taibai::new(net)
        .weights(weights)
        .merge(false)
        .sa_iters(0)
        .build()
        .expect("single-die delayed skip must compile");
    assert_eq!(session.info().chips, 1);
}
