//! Integration: the three-layer contract. Loads the AOT artifacts
//! (Pallas kernel → JAX → HLO text) through the PJRT runtime and checks
//! their numerics against (a) a Rust-side reference and (b) the detailed
//! chip engine running the same LIF dynamics through the ISA programs —
//! i.e. L1 ⇔ L2 ⇔ L3 agree.
//!
//! Skips cleanly when `make artifacts` has not run. The tests that load
//! HLO artifacts through PJRT additionally need the `pjrt` cargo
//! feature; the chip-vs-reference cross-check and the weight-artifact
//! checks run on the dependency-free default build.

use taibai::runtime::artifacts::artifacts_dir;
#[cfg(feature = "pjrt")]
use taibai::runtime::Engine;

#[cfg(feature = "pjrt")]
fn artifact(name: &str) -> Option<String> {
    let p = artifacts_dir().join(name);
    p.exists().then(|| p.to_string_lossy().into_owned())
}

/// Rust-side oracle of the fused LIF step (mirrors kernels/ref.py).
fn lif_step_ref(
    s: &[f32],
    w: &[f32],
    v: &[f32],
    b: usize,
    k: usize,
    n: usize,
    tau: f32,
    vth: f32,
) -> (Vec<f32>, Vec<f32>) {
    let mut v_out = vec![0.0f32; b * n];
    let mut spk = vec![0.0f32; b * n];
    for bi in 0..b {
        for ni in 0..n {
            let mut i = 0.0;
            for ki in 0..k {
                i += s[bi * k + ki] * w[ki * n + ni];
            }
            let vn = tau * v[bi * n + ni] + i;
            if vn >= vth {
                spk[bi * n + ni] = 1.0;
                v_out[bi * n + ni] = 0.0;
            } else {
                v_out[bi * n + ni] = vn;
            }
        }
    }
    (v_out, spk)
}

#[cfg(feature = "pjrt")]
#[test]
fn pallas_artifact_matches_rust_reference() {
    let Some(path) = artifact("lif_step.hlo.txt") else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let engine = Engine::cpu().expect("PJRT client");
    let exe = engine.load_hlo(&path).expect("compile artifact");

    let (b, k, n) = (8usize, 128usize, 128usize);
    let mut rng = taibai::util::Rng::new(123);
    let s: Vec<f32> = (0..b * k).map(|_| if rng.chance(0.12) { 1.0 } else { 0.0 }).collect();
    let w: Vec<f32> = (0..k * n).map(|_| (rng.f32() - 0.5) * 0.4).collect();
    let v: Vec<f32> = (0..b * n).map(|_| (rng.f32() - 0.5) * 0.8).collect();
    let tau = [0.9f32];
    let vth = [1.0f32];

    let out = exe
        .run_f32(&[
            (&s, &[b as i64, k as i64]),
            (&w, &[k as i64, n as i64]),
            (&v, &[b as i64, n as i64]),
            (&tau, &[1]),
            (&vth, &[1]),
        ])
        .expect("execute artifact");
    assert_eq!(out.len(), 2, "artifact returns (v_next, spikes)");

    let (v_ref, s_ref) = lif_step_ref(&s, &w, &v, b, k, n, 0.9, 1.0);
    let mut max_err = 0.0f32;
    for (a, r) in out[0].iter().zip(&v_ref) {
        max_err = max_err.max((a - r).abs());
    }
    assert!(max_err < 1e-4, "membrane mismatch: {max_err}");
    let spike_flips = out[1]
        .iter()
        .zip(&s_ref)
        .filter(|(a, r)| (*a - *r).abs() > 0.5)
        .count();
    assert!(spike_flips <= 1, "spike mismatch count {spike_flips}");
}

#[test]
fn chip_engine_matches_pallas_artifact_dynamics() {
    // Layer-3 check: a 4->8 LIF layer deployed through the compiler on
    // the ISA engine must reproduce the same spike/membrane trajectory
    // as the reference dynamics (which the artifact test above ties to
    // the Pallas kernel). FP16 on chip vs f32 reference: tolerance.
    use taibai::compiler::{self, Options};
    use taibai::coordinator::Deployment;
    use taibai::datasets::SpikeSample;
    use taibai::model::{Layer, NetDef, NeuronModel};

    let (k, n) = (4usize, 8usize);
    let tau = 0.5f32;
    let vth = 1.0f32;
    let mut rng = taibai::util::Rng::new(5);
    let w: Vec<f32> = (0..k * n).map(|_| (rng.f32() * 0.9) - 0.2).collect();

    let mut net = NetDef::new("xcheck", 12);
    net.layers.push(Layer::Input { size: k });
    net.layers.push(Layer::Fc {
        input: k,
        output: n,
        neuron: NeuronModel::Lif { tau, vth },
    });
    let r = compiler::compile(&net, &vec![vec![], w.clone()], &Options::default()).unwrap();
    let mut d = Deployment::new(r.compiled).unwrap();

    // random spike train
    let t_steps = 12;
    let mut spikes = Vec::new();
    for _ in 0..t_steps {
        let mut at = Vec::new();
        for ch in 0..k as u16 {
            if rng.chance(0.5) {
                at.push(ch);
            }
        }
        spikes.push(at);
    }

    // reference trajectory
    let mut v = vec![0.0f32; n];
    let mut ref_spikes: Vec<Vec<usize>> = Vec::new();
    for t in 0..t_steps {
        let mut s_in = vec![0.0f32; k];
        for &ch in &spikes[t] {
            s_in[ch as usize] = 1.0;
        }
        let (v2, spk) = lif_step_ref(&s_in, &w, &v, 1, k, n, tau, vth);
        v = v2;
        ref_spikes.push(
            spk.iter()
                .enumerate()
                .filter(|(_, &x)| x > 0.5)
                .map(|(i, _)| i)
                .collect(),
        );
    }
    let ref_total: usize = ref_spikes.iter().map(|s| s.len()).sum();

    // chip trajectory (spike counts per step via run stats)
    let run = d
        .run_spikes(&SpikeSample { spikes, labels: vec![0] })
        .expect("chip run");
    // output layer has empty fan-out (host) — count host spikes? The
    // layer is terminal with LIF (spiking); its spikes go nowhere, so
    // compare total fired via chip activity.
    let chip_total = d.chip.activity().nc.spikes_out as usize;
    let _ = run;
    assert!(
        (chip_total as i64 - ref_total as i64).abs() <= (ref_total / 10 + 2) as i64,
        "chip {} vs reference {} spikes",
        chip_total,
        ref_total
    );
}

#[cfg(feature = "pjrt")]
#[test]
fn srnn_and_bci_artifacts_compile_and_execute() {
    for name in ["srnn_step.hlo.txt", "bci_step.hlo.txt"] {
        let Some(path) = artifact(name) else {
            eprintln!("skipping {name}: run `make artifacts`");
            return;
        };
        let engine = Engine::cpu().unwrap();
        let exe = engine.load_hlo(&path).expect("compile");
        if name.starts_with("srnn") {
            let x = vec![1.0f32; 4];
            let w1 = vec![0.05f32; 68 * 64];
            let w2 = vec![0.05f32; 64 * 6];
            let z64 = vec![0.0f32; 64];
            let z6 = vec![0.0f32; 6];
            let out = exe
                .run_f32(&[
                    (&x, &[4]),
                    (&w1, &[68, 64]),
                    (&w2, &[64, 6]),
                    (&z64, &[64]),
                    (&z64, &[64]),
                    (&z64, &[64]),
                    (&z6, &[6]),
                ])
                .expect("run srnn step");
            assert_eq!(out.len(), 4);
            assert_eq!(out[0].len(), 64);
        }
    }
}

#[test]
fn trained_weights_load_with_expected_shapes() {
    use taibai::runtime::artifacts::read_weights;
    let dir = artifacts_dir().join("weights");
    if !dir.exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    for (stem, expect) in [
        ("ecg_srnn_w1", (4 + 64) * 64),
        ("ecg_srnn_w2", 64 * 6),
        ("shd_dhsnn_w1", 4 * 700 * 64),
        ("shd_dhsnn_w2", 64 * 20),
        ("bci_w1", 128 * 128),
        ("bci_w3", 128 * 4),
    ] {
        let w = read_weights(&dir.join(format!("{stem}.bin"))).expect(stem);
        assert_eq!(w.len(), expect, "{stem}");
        assert!(w.iter().any(|&x| x != 0.0), "{stem} all zeros");
    }
}
