//! Gateway concurrency-correctness: the sharded serving front-end must
//! never trade correctness for throughput.
//!
//! * Interleaved ECG / SHD / BCI tenant streams pushed through a
//!   multi-threaded `Gateway` decode **bit-identically** to sequential
//!   single-session runs — threading changes scheduling, never results.
//! * Admission control rejections (`Saturated`, `QueueFull`,
//!   `DeadlineExceeded`) and stale handles surface as typed errors
//!   across the thread boundary, and the telemetry accounting
//!   reconciles: every routed request lands in exactly one bucket.
//! * A learning tenant's on-chip fine-tune is confined to its own
//!   stream: the gateway checkpoints a slot's weights at admission and
//!   restores them at release. The control half of that test shows the
//!   bare single `SessionPool` *leaking* the fine-tune into the next
//!   tenant on the slot — so the isolation pin cannot pass on the
//!   unsharded pool.

use std::time::Duration;

use taibai::api::workloads::{Bci, Ecg, Shd, Workload};
use taibai::api::{
    Backend, Gateway, GatewayConfig, GatewayError, Rejected, Sample, SessionPool,
    StreamReport,
};
use taibai::metrics::argmax;

fn gw_cfg(workers: usize, slots: usize, depth: usize) -> GatewayConfig {
    GatewayConfig {
        workers,
        slots_per_worker: slots,
        queue_depth: depth,
        deadline: None,
    }
}

/// Serve one whole sample on a bare pool (open → push-all → release).
fn serve_whole(pool: &mut SessionPool, s: &Sample) -> StreamReport {
    let id = pool.open().expect("open");
    for t in 0..s.timesteps() {
        pool.push(id, s.events_at(t)).expect("push");
    }
    pool.release(id).expect("release")
}

#[test]
fn gateway_streams_match_sequential_sessions_across_workloads() {
    // 2 tenants per workload stream concurrently over a 2-worker
    // gateway, pushes interleaved per timestep across the shard
    // threads; each must decode exactly what its own private
    // sequential session decodes (rows aggregated, spikes, packets).
    let seed = 29;
    let workloads: Vec<Box<dyn Workload>> = vec![
        Box::new(Ecg {
            heterogeneous: true,
        }),
        Box::new(Shd { dendrites: true }),
        Box::new(Bci::default()),
    ];
    for (wi, w) in workloads.iter().enumerate() {
        let data: Vec<Sample> = w.dataset(2, seed).into_iter().take(2).collect();

        let mut seq = w.session(Backend::Detailed, seed).unwrap();
        let mut expected = Vec::new();
        for s in &data {
            let run = seq.run(s).unwrap();
            expected.push((argmax(&run.summed()), run.spikes, run.packets));
        }

        let template = w.session(Backend::Detailed, seed).unwrap();
        let gw = Gateway::new(&template, gw_cfg(2, data.len(), 16)).unwrap();
        let handles: Vec<_> = data
            .iter()
            .enumerate()
            .map(|(k, _)| {
                gw.open(k as u64 * 7 + wi as u64).expect("admission")
            })
            .collect();
        let t_max = data.iter().map(|s| s.timesteps()).max().unwrap();
        for t in 0..t_max {
            for (k, s) in data.iter().enumerate() {
                if t < s.timesteps() {
                    gw.push(handles[k], s.events_at(t)).expect("push");
                }
            }
        }
        for (k, s) in data.iter().enumerate() {
            let rep = gw.release(handles[k]).expect("release");
            let (cls, conf) = rep.decision.expect("gateway stream must decode");
            let tag = format!("{} stream {k}", w.name());
            assert_eq!(
                cls, expected[k].0,
                "{tag}: decoded label diverged from the sequential session"
            );
            assert!(conf > 0.0 && conf <= 1.0, "{tag}: confidence out of range");
            assert_eq!(rep.spikes, expected[k].1, "{tag}: spikes diverged");
            assert_eq!(rep.packets, expected[k].2, "{tag}: packets diverged");
            assert_eq!(rep.steps as usize, s.timesteps(), "{tag}: steps");
        }
        let t = gw.telemetry();
        assert!(t.reconciled(), "{}: accounting does not reconcile", w.name());
        assert_eq!(t.stats.completed, data.len() as u64);
        assert_eq!(t.rejected.total(), 0);
    }
}

#[test]
fn gateway_rejections_saturated_and_stale_cross_thread() {
    let w = Shd { dendrites: true };
    let template = w.session(Backend::Detailed, 5).unwrap();
    let sample = w.dataset(1, 5).remove(0);
    let gw = Gateway::new(&template, gw_cfg(1, 1, 8)).unwrap();

    let a = gw.open(1).unwrap();
    match gw.open(2) {
        Err(GatewayError::Rejected(Rejected::Saturated)) => {}
        other => panic!("second open on a full 1-slot shard: {other:?}"),
    }
    gw.push(a, sample.events_at(0)).unwrap();
    let rep = gw.release(a).unwrap();
    assert_eq!(rep.steps, 1);
    // the handle is stale now — the slot may belong to someone else
    match gw.release(a) {
        Err(GatewayError::StaleStream) => {}
        other => panic!("release of a released handle: {other:?}"),
    }
    match gw.push(a, sample.events_at(0)) {
        Err(GatewayError::StaleStream) => {}
        other => panic!("push on a released handle: {other:?}"),
    }

    let t = gw.telemetry();
    assert_eq!(t.attempts, 2);
    assert_eq!(t.stats.opened, 1);
    assert_eq!(t.rejected.saturated, 1);
    assert_eq!(t.rejected.queue_full + t.rejected.deadline, 0);
    assert!(t.reconciled());
}

#[test]
fn gateway_zero_deadline_rejects_submissions() {
    let w = Shd { dendrites: true };
    let template = w.session(Backend::Detailed, 5).unwrap();
    let sample = w.dataset(1, 5).remove(0);
    let gw = Gateway::new(
        &template,
        GatewayConfig {
            deadline: Some(Duration::ZERO),
            ..gw_cfg(1, 1, 8)
        },
    )
    .unwrap();

    let tickets: Vec<_> = (0..3)
        .map(|i| gw.submit(i, sample.clone(), None).expect("queued"))
        .collect();
    for ticket in tickets {
        match ticket.wait() {
            Err(GatewayError::Rejected(Rejected::DeadlineExceeded)) => {}
            other => panic!("zero deadline must reject at dequeue: {other:?}"),
        }
    }
    let t = gw.telemetry();
    assert_eq!(t.rejected.deadline, 3);
    assert_eq!(t.stats.opened, 0);
    assert!(t.reconciled());
}

#[test]
fn gateway_sheds_queue_full_under_burst() {
    let w = Shd { dendrites: true };
    let template = w.session(Backend::Detailed, 7).unwrap();
    let sample = w.dataset(1, 7).remove(0);
    // depth-1 queue, one worker busy for ~a full sample per request:
    // an instant burst must shed most of itself at the door
    let gw = Gateway::new(&template, gw_cfg(1, 1, 1)).unwrap();

    let mut tickets = Vec::new();
    let mut shed = 0u64;
    let burst = 24u64;
    for i in 0..burst {
        match gw.submit(i, sample.clone(), None) {
            Ok(t) => tickets.push(t),
            Err(GatewayError::Rejected(Rejected::QueueFull)) => shed += 1,
            Err(e) => panic!("submit: {e}"),
        }
    }
    assert!(
        shed > 0,
        "{burst} back-to-back submits never filled a depth-1 queue"
    );
    for ticket in tickets {
        ticket.wait().expect("admitted streams must complete");
    }
    let t = gw.telemetry();
    assert_eq!(t.attempts, burst);
    assert_eq!(t.rejected.queue_full, shed);
    assert_eq!(t.stats.opened, burst - shed);
    assert_eq!(t.stats.completed, burst - shed);
    assert!(t.reconciled());
}

#[test]
fn gateway_isolates_learning_tenants_where_bare_pool_leaks() {
    // Tenant A fine-tunes on its stream, then tenant B lands on the
    // same slot. On the bare single pool the fine-tune persists into
    // B's decode (the control — this pin CANNOT pass there); the
    // gateway restores the slot's pre-admission weights at release, so
    // B bit-matches a pool that never saw A.
    let w = Bci::default();
    let seed = 11;
    let data = w.dataset(4, seed);
    let (sample_a, sample_b) = (&data[1], &data[0]);
    let errors = [1.5f32, -1.5, 1.5, -1.5];

    // reference: a fresh pool serving only tenant B
    let mut fresh =
        SessionPool::new(w.session(Backend::Detailed, seed).unwrap(), 1).unwrap();
    let reference = serve_whole(&mut fresh, sample_b);
    assert!(reference.decision.is_some());

    // control: bare pool — A's learn updates leak into B's slot
    let mut bare =
        SessionPool::new(w.session(Backend::Detailed, seed).unwrap(), 1).unwrap();
    let id = bare.open().unwrap();
    for t in 0..sample_a.timesteps() {
        bare.push(id, sample_a.events_at(t)).unwrap();
    }
    for _ in 0..4 {
        bare.learn(id, &errors).unwrap();
    }
    bare.release(id).unwrap();
    let leaked = serve_whole(&mut bare, sample_b);
    assert!(
        leaked.spikes != reference.spikes || leaked.decision != reference.decision,
        "control lost its teeth: tenant A's fine-tune left no visible trace \
         on the bare pool, so the isolation pin below pins nothing"
    );

    // gateway: same protocol, same (only) slot — isolated
    let template = w.session(Backend::Detailed, seed).unwrap();
    let gw = Gateway::new(&template, gw_cfg(1, 1, 8)).unwrap();
    let a = gw.open(1).unwrap();
    for t in 0..sample_a.timesteps() {
        gw.push(a, sample_a.events_at(t)).unwrap();
    }
    for _ in 0..4 {
        gw.learn(a, &errors).unwrap();
    }
    gw.release(a).unwrap();
    let b = gw.open(2).unwrap();
    assert_eq!(b.slot(), a.slot(), "B must reuse A's slot for the pin to bite");
    for t in 0..sample_b.timesteps() {
        gw.push(b, sample_b.events_at(t)).unwrap();
    }
    let rep = gw.release(b).unwrap();
    assert_eq!(
        rep.spikes, reference.spikes,
        "gateway leaked tenant A's fine-tune into tenant B (spikes)"
    );
    assert_eq!(
        rep.decision, reference.decision,
        "gateway leaked tenant A's fine-tune into tenant B (decision)"
    );
    let t = gw.telemetry();
    assert_eq!(t.stats.completed, 2);
    assert!(t.reconciled());
}

#[test]
fn stale_release_replay_cannot_clobber_active_tenants_checkpoint() {
    // TenantStream is Copy, so a released handle can be replayed after
    // the slot was re-admitted to someone else. The replay must fail
    // with StaleStream and leave the slot's checkpoint alone: consuming
    // it would (a) restore pre-admission weights mid-stream under the
    // active tenant and (b) disarm that tenant's real release, leaking
    // its fine-tune into the next admission.
    let w = Bci::default();
    let seed = 11;
    let data = w.dataset(4, seed);
    let (sample_b, sample_c) = (&data[1], &data[0]);
    let errors = [1.5f32, -1.5, 1.5, -1.5];

    // reference: what tenant C decodes on a pool that never saw B
    let mut fresh =
        SessionPool::new(w.session(Backend::Detailed, seed).unwrap(), 1).unwrap();
    let reference = serve_whole(&mut fresh, sample_c);
    assert!(reference.decision.is_some());

    let template = w.session(Backend::Detailed, seed).unwrap();
    let gw = Gateway::new(&template, gw_cfg(1, 1, 8)).unwrap();

    // A opens and releases; its Copy handle is now stale
    let a = gw.open(1).unwrap();
    gw.push(a, sample_c.events_at(0)).unwrap();
    gw.release(a).unwrap();

    // B is admitted on the same slot
    let b = gw.open(2).unwrap();
    assert_eq!(b.slot(), a.slot());
    for t in 0..sample_b.timesteps() {
        gw.push(b, sample_b.events_at(t)).unwrap();
    }

    // replaying A's dead handle mid-stream must be a pure no-op
    match gw.release(a) {
        Err(GatewayError::StaleStream) => {}
        other => panic!("replayed stale release: {other:?}"),
    }

    // B fine-tunes *after* the replay: if the replay consumed B's
    // checkpoint, this fine-tune has nothing left to undo it and leaks
    for _ in 0..4 {
        gw.learn(b, &errors).unwrap();
    }

    // B's real release must still restore the slot, so C bit-matches
    // the fresh-pool reference
    gw.release(b).unwrap();
    let c = gw.open(3).unwrap();
    assert_eq!(c.slot(), b.slot());
    for t in 0..sample_c.timesteps() {
        gw.push(c, sample_c.events_at(t)).unwrap();
    }
    let rep = gw.release(c).unwrap();
    assert_eq!(
        rep.spikes, reference.spikes,
        "stale replay consumed B's checkpoint: B's fine-tune leaked into C"
    );
    assert_eq!(rep.decision, reference.decision);
    let t = gw.telemetry();
    assert_eq!(t.stats.completed, 3);
    assert!(t.reconciled(), "{t:?}");
}

#[test]
fn sharded_backend_weight_checkpoint_roundtrip() {
    // checkpoint/restore must also work on the lockstep multi-die
    // engine (per-chip peek/poke over merged layouts), and restoring
    // an untouched checkpoint must be a bit-exact no-op.
    let w = Shd { dendrites: true };
    let mut s = w.session(Backend::Sharded { chips: 2 }, 13).unwrap();
    let sample = w.dataset(1, 13).remove(0);

    let before = s.run(&sample).unwrap();
    let ckpt = s
        .checkpoint_weights()
        .unwrap()
        .expect("the detailed engines expose weight checkpoints");
    assert!(ckpt.words() > 0, "checkpoint captured no weight words");
    s.restore_weights(&ckpt).unwrap();
    let after = s.run(&sample).unwrap();
    assert_eq!(
        before.outputs, after.outputs,
        "restoring an untouched checkpoint perturbed the deployment"
    );
    assert_eq!(before.spikes, after.spikes);
}
