//! Streaming-vs-batch parity: the tentpole contract of the event-driven
//! Session API.
//!
//! `Session::run` is a thin wrapper over the same `begin/step/finish`
//! backend contract `Session::open_stream` exposes, so pushing a sample
//! one timestep at a time must reproduce the batch run **bit-exactly**:
//! identical readout rows, identical spike/packet counts, identical
//! `ChipActivity` and scheduler counters — on every workload and on
//! both detailed engines (single-die and lockstep-sharded). On top of
//! that, `serve::SessionPool` multiplexing N interleaved client streams
//! must decode exactly what N sequential sessions decode (per-stream
//! isolation leaves no cross-tenant trace).

use taibai::api::workloads::{Bci, Ecg, Shd, Workload};
use taibai::api::{Backend, Sample, SessionPool};
use taibai::metrics::argmax;

/// Stream every sample push-per-step next to a batch `run` on a twin
/// session and pin rows, counts, and whole-session activity.
fn assert_stream_parity(w: &dyn Workload, backend: Backend, samples: usize) {
    let seed = 17;
    let mut batch = w
        .session(backend, seed)
        .unwrap_or_else(|e| panic!("{} on {backend}: {e}", w.name()));
    let mut streaming = w.session(backend, seed).unwrap();
    let data = w.dataset(samples, seed);
    for (si, s) in data.iter().take(samples).enumerate() {
        let run = batch.run(s).expect("batch run");

        let mut stream = streaming.open_stream().expect("open stream");
        let mut rows = Vec::with_capacity(s.timesteps());
        for t in 0..s.timesteps() {
            let out = stream.push(s.events_at(t)).expect("push");
            if let Some(row) = &out.row {
                rows.push(row.clone());
            }
        }
        let rep = stream.finish().expect("finish");

        let tag = format!("{} {backend}: sample {si}", w.name());
        assert_eq!(run.outputs, rows, "{tag}: readout rows diverged");
        assert_eq!(run.spikes, rep.spikes, "{tag}: spike counts diverged");
        assert_eq!(run.packets, rep.packets, "{tag}: packet counts diverged");
        assert_eq!(rep.steps as usize, s.timesteps(), "{tag}: step count");
    }
    let tag = format!("{} {backend}", w.name());
    assert_eq!(
        batch.activity(),
        streaming.activity(),
        "{tag}: ChipActivity diverged"
    );
    assert_eq!(
        batch.telemetry().sched,
        streaming.telemetry().sched,
        "{tag}: scheduler counters diverged"
    );
    assert_eq!(batch.samples_run(), streaming.samples_run(), "{tag}: samples");
}

#[test]
fn ecg_stream_matches_batch_detailed() {
    assert_stream_parity(&Ecg { heterogeneous: true }, Backend::Detailed, 1);
}

#[test]
fn shd_stream_matches_batch_detailed() {
    assert_stream_parity(&Shd { dendrites: true }, Backend::Detailed, 2);
}

#[test]
fn bci_stream_matches_batch_detailed() {
    assert_stream_parity(&Bci { subpaths: 8, day: 2 }, Backend::Detailed, 2);
}

#[test]
fn ecg_stream_matches_batch_sharded() {
    assert_stream_parity(
        &Ecg { heterogeneous: true },
        Backend::Sharded { chips: 2 },
        1,
    );
}

#[test]
fn shd_stream_matches_batch_sharded() {
    assert_stream_parity(&Shd { dendrites: true }, Backend::Sharded { chips: 2 }, 2);
}

#[test]
fn bci_stream_matches_batch_sharded() {
    assert_stream_parity(
        &Bci { subpaths: 8, day: 2 },
        Backend::Sharded { chips: 2 },
        2,
    );
}

#[test]
fn run_batch_workers_match_streams() {
    // the forked-worker path (`run_batch`) goes through the same
    // begin/step/finish loop — pin it against hand-driven streams
    let w = Shd { dendrites: true };
    let seed = 29;
    let data: Vec<Sample> = w.dataset(4, seed).into_iter().take(4).collect();

    let mut streaming = w.session(Backend::Detailed, seed).unwrap();
    let mut expected = Vec::new();
    for s in &data {
        let mut stream = streaming.open_stream().unwrap();
        let mut rows = Vec::new();
        for t in 0..s.timesteps() {
            let out = stream.push(s.events_at(t)).unwrap();
            rows.push(out.row.clone().unwrap());
        }
        let rep = stream.finish().unwrap();
        expected.push((rows, rep.spikes));
    }

    let mut batch = w.session(Backend::Detailed, seed).unwrap();
    let got = batch.run_batch(&data).unwrap();
    assert_eq!(got.len(), expected.len());
    for (i, (g, (rows, spikes))) in got.iter().zip(&expected).enumerate() {
        assert_eq!(&g.outputs, rows, "sample {i}: worker rows diverged");
        assert_eq!(g.spikes, *spikes, "sample {i}: worker spikes diverged");
    }
    assert_eq!(batch.activity().nc.sops, streaming.activity().nc.sops);
}

#[test]
fn pool_interleaved_streams_match_sequential_sessions() {
    // 4 clients stream concurrently over a 4-deployment pool, pushes
    // interleaved round-robin per timestep; each must decode exactly
    // what its own private sequential session decodes
    let w = Shd { dendrites: true };
    let seed = 23;
    let data: Vec<Sample> = w.dataset(4, seed).into_iter().take(4).collect();

    let mut seq = w.session(Backend::Detailed, seed).unwrap();
    let mut expected = Vec::new();
    for s in &data {
        let run = seq.run(s).unwrap();
        expected.push((argmax(&run.summed()), run.spikes, run.packets));
    }

    let template = w.session(Backend::Detailed, seed).unwrap();
    let mut pool = SessionPool::new(template, data.len()).unwrap();
    let ids: Vec<_> = data.iter().map(|_| pool.open().unwrap()).collect();
    let t_max = data.iter().map(|s| s.timesteps()).max().unwrap();
    for t in 0..t_max {
        for (k, s) in data.iter().enumerate() {
            if t < s.timesteps() {
                pool.push(ids[k], s.events_at(t)).unwrap();
            }
        }
    }
    for (k, s) in data.iter().enumerate() {
        let rep = pool.release(ids[k]).unwrap();
        let (cls, conf) = rep.decision.expect("pool stream must decode");
        assert_eq!(
            cls, expected[k].0,
            "stream {k}: decoded label diverged from the sequential session"
        );
        assert!(conf > 0.0 && conf <= 1.0);
        assert_eq!(rep.spikes, expected[k].1, "stream {k}: spikes diverged");
        assert_eq!(rep.packets, expected[k].2, "stream {k}: packets diverged");
        assert_eq!(rep.steps as usize, s.timesteps());
    }
    let st = pool.telemetry().stats;
    assert_eq!(st.peak_active, data.len());
    assert_eq!(st.completed, data.len() as u64);
    assert_eq!(st.rejected, 0);
    // all four tenants' work is visible in the pool-level activity
    assert_eq!(
        pool.activity().nc.sops,
        seq.activity().nc.sops,
        "pool aggregate activity diverged from the sequential reference"
    );
}
